//! Primal/dual objectives and the duality-gap certificate (Section 2).
//!
//! `P(w) = (lambda/2)||w||^2 + (1/n) sum_i loss(x_i^T w, y_i)`
//! `D(a) = -(lambda/2)||A a||^2 - (1/n) sum_i conj(-a_i)`
//!
//! The gap `P(w(a)) - D(a) >= 0` certifies suboptimality without knowing
//! the optimum — the paper's recommended stopping criterion. The
//! distributed runtime evaluates these via per-block partial sums
//! (mirroring the `eval_objectives` PJRT artifact); the whole-dataset
//! functions here are the reference used by tests and the optimum cache.

use crate::data::Dataset;
use crate::loss::Loss;

/// `sum_i loss(x_i^T w, y_i)` over a block — one of the two partial sums a
/// worker reports during evaluation.
pub fn block_loss_sum(data: &Dataset, w: &[f64], loss: &dyn Loss) -> f64 {
    (0..data.n())
        .map(|i| loss.value(data.features.row_dot(i, w), data.labels[i]))
        .sum()
}

/// `sum_i conj(-alpha_i)` over a block — the other partial sum.
pub fn block_conj_sum(data: &Dataset, alpha: &[f64], loss: &dyn Loss) -> f64 {
    data.labels
        .iter()
        .zip(alpha)
        .map(|(&y, &a)| loss.conjugate(a, y))
        .sum()
}

/// Combine partial sums into the primal value.
pub fn primal_from_partials(loss_sum: f64, w_norm_sq: f64, lambda: f64, n: usize) -> f64 {
    0.5 * lambda * w_norm_sq + loss_sum / n as f64
}

/// Combine partial sums into the dual value.
pub fn dual_from_partials(conj_sum: f64, w_norm_sq: f64, lambda: f64, n: usize) -> f64 {
    -0.5 * lambda * w_norm_sq - conj_sum / n as f64
}

/// Full primal objective on one dataset.
pub fn primal(data: &Dataset, w: &[f64], lambda: f64, loss: &dyn Loss) -> f64 {
    let w_norm_sq: f64 = w.iter().map(|v| v * v).sum();
    primal_from_partials(block_loss_sum(data, w, loss), w_norm_sq, lambda, data.n())
}

/// Full dual objective; recomputes `w = A alpha` internally.
pub fn dual(data: &Dataset, alpha: &[f64], lambda: f64, loss: &dyn Loss) -> f64 {
    let w = data.primal_from_dual(alpha, lambda);
    let w_norm_sq: f64 = w.iter().map(|v| v * v).sum();
    dual_from_partials(block_conj_sum(data, alpha, loss), w_norm_sq, lambda, data.n())
}

/// Duality gap `P(w(a)) - D(a)`.
pub fn duality_gap(data: &Dataset, alpha: &[f64], lambda: f64, loss: &dyn Loss) -> f64 {
    let w = data.primal_from_dual(alpha, lambda);
    primal(data, &w, lambda, loss) - dual(data, alpha, lambda, loss)
}

/// Reference optimum: single-machine permutation SDCA until the duality
/// gap falls below `tol`. Used to compute the `P*` that the figures'
/// "primal suboptimality" axis is measured against.
pub fn compute_optimum(
    data: &Dataset,
    lambda: f64,
    loss: &dyn Loss,
    tol: f64,
    max_passes: usize,
) -> (f64, Vec<f64>) {
    use crate::solvers::{Block, ExactBlockSolver, LocalDualMethod};

    let n = data.n();
    let block = Block::new(data.clone(), lambda * n as f64);
    let solver = ExactBlockSolver { tol: 0.0, max_passes: 1 };
    let mut alpha = vec![0.0; n];
    let mut w = vec![0.0; data.d()];
    let mut rng = crate::util::Rng::seed_from_u64(0x0c0c0a);
    let mut best_primal = f64::INFINITY;
    for _ in 0..max_passes {
        let up = solver.local_update(&block, loss, &alpha, &w, n, &mut rng);
        for (a, da) in alpha.iter_mut().zip(&up.dalpha) {
            *a += da;
        }
        for (wv, dv) in w.iter_mut().zip(&up.dw) {
            *wv += dv;
        }
        let p = primal(data, &w, lambda, loss);
        let d = dual_from_partials(
            block_conj_sum(data, &alpha, loss),
            w.iter().map(|v| v * v).sum(),
            lambda,
            n,
        );
        best_primal = best_primal.min(p);
        if p - d < tol {
            break;
        }
    }
    (best_primal, w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::cov_like;
    use crate::loss::{Hinge, SmoothedHinge, Squared};

    #[test]
    fn gap_nonnegative_at_feasible_points() {
        let data = cov_like(80, 6, 0.1, 1);
        let lambda = 0.1;
        for loss in [&Hinge as &dyn crate::loss::Loss, &Squared] {
            let alpha: Vec<f64> = data.labels.iter().map(|y| 0.3 * y).collect();
            assert!(duality_gap(&data, &alpha, lambda, loss) >= -1e-12);
        }
    }

    #[test]
    fn zero_alpha_gap_is_one_for_hinge() {
        // P(0) = 1 (all margins 0), D(0) = 0 => gap = 1 (the paper's
        // D(a*) - D(0) <= 1 normalization, Lemma 20 of SSZ13).
        let data = cov_like(50, 5, 0.1, 2);
        let gap = duality_gap(&data, &vec![0.0; 50], 0.1, &Hinge);
        assert!((gap - 1.0).abs() < 1e-12);
    }

    #[test]
    fn partials_compose_to_full_objective() {
        let data = cov_like(60, 6, 0.1, 3);
        let lambda = 0.05;
        let loss = SmoothedHinge::new(0.5);
        let alpha: Vec<f64> = data.labels.iter().map(|y| 0.2 * y).collect();
        let w = data.primal_from_dual(&alpha, lambda);
        let w_norm_sq: f64 = w.iter().map(|v| v * v).sum();
        // split into two pseudo-blocks and combine
        let idx_a: Vec<u32> = (0..30).collect();
        let idx_b: Vec<u32> = (30..60).collect();
        let (da, db) = (data.subset(&idx_a), data.subset(&idx_b));
        let ls = block_loss_sum(&da, &w, &loss) + block_loss_sum(&db, &w, &loss);
        let cs = block_conj_sum(&da, &alpha[..30], &loss)
            + block_conj_sum(&db, &alpha[30..], &loss);
        let p = primal_from_partials(ls, w_norm_sq, lambda, 60);
        let d = dual_from_partials(cs, w_norm_sq, lambda, 60);
        assert!((p - primal(&data, &w, lambda, &loss)).abs() < 1e-10);
        assert!((d - dual(&data, &alpha, lambda, &loss)).abs() < 1e-10);
    }

    #[test]
    fn compute_optimum_closes_gap() {
        let data = cov_like(120, 8, 0.05, 4);
        let lambda = 0.1;
        let (p_star, w_star) = compute_optimum(&data, lambda, &Hinge, 1e-8, 400);
        assert!(p_star.is_finite());
        // optimum must not exceed the value at any feasible w we can try
        let p0 = primal(&data, &vec![0.0; 8], lambda, &Hinge);
        assert!(p_star <= p0);
        assert!(primal(&data, &w_star, lambda, &Hinge) <= p0);
    }
}

// ---------------------------------------------------------------------------
// Regularizer-aware objectives — the generalized primal-dual pair the
// regularizers subsystem opens up:
//
//   P(w) = lambda_eff * [ (1/2)||w||^2 + kappa||w||_1 ] + (1/n) sum_i loss_i
//   D(a) = -(lambda_eff/2) ||prox(v(a))||^2 - (1/n) sum_i conj(-a_i)
//
// with `lambda_eff = lambda * sigma`, `v(a) = (1/(lambda_eff n)) sum a_i x_i`
// and `w = prox(v) = soft(v, kappa)` (see `crate::regularizers`). For the
// L2 regularizer (`kappa = 0`, `sigma = 1`) every function below reduces
// bit-for-bit to its plain counterpart above.

use crate::regularizers::{l1_norm, Regularizer};

/// Combine partial sums into the regularized primal value. `w_l1` is
/// `||w||_1`; for `kappa = 0` this is exactly [`primal_from_partials`]
/// (same arithmetic, bit for bit).
pub fn primal_from_partials_reg(
    loss_sum: f64,
    w_norm_sq: f64,
    w_l1: f64,
    lambda_eff: f64,
    kappa: f64,
    n: usize,
) -> f64 {
    if kappa == 0.0 {
        primal_from_partials(loss_sum, w_norm_sq, lambda_eff, n)
    } else {
        0.5 * lambda_eff * w_norm_sq + lambda_eff * kappa * w_l1 + loss_sum / n as f64
    }
}

/// Full regularized primal objective at a primal point `w`.
pub fn primal_reg(
    data: &Dataset,
    w: &[f64],
    lambda: f64,
    reg: &dyn Regularizer,
    loss: &dyn Loss,
) -> f64 {
    let lambda_eff = lambda * reg.strong_convexity();
    let w_norm_sq: f64 = w.iter().map(|v| v * v).sum();
    primal_from_partials_reg(
        block_loss_sum(data, w, loss),
        w_norm_sq,
        l1_norm(w),
        lambda_eff,
        reg.l1_weight(),
        data.n(),
    )
}

/// Full regularized dual objective; recomputes `v = A alpha` (in the
/// `lambda_eff` scaling) and maps it through the prox internally.
pub fn dual_reg(
    data: &Dataset,
    alpha: &[f64],
    lambda: f64,
    reg: &dyn Regularizer,
    loss: &dyn Loss,
) -> f64 {
    let lambda_eff = lambda * reg.strong_convexity();
    let v = data.primal_from_dual(alpha, lambda_eff);
    let mut w = vec![0.0; v.len()];
    reg.prox_into(&v, &mut w);
    let w_norm_sq: f64 = w.iter().map(|x| x * x).sum();
    dual_from_partials(block_conj_sum(data, alpha, loss), w_norm_sq, lambda_eff, data.n())
}

/// Regularized duality gap `P(prox(v(a))) - D(a) >= 0` (Fenchel duality of
/// the normalized pair — the stopping certificate for lasso/elastic-net
/// runs).
pub fn duality_gap_reg(
    data: &Dataset,
    alpha: &[f64],
    lambda: f64,
    reg: &dyn Regularizer,
    loss: &dyn Loss,
) -> f64 {
    let lambda_eff = lambda * reg.strong_convexity();
    let v = data.primal_from_dual(alpha, lambda_eff);
    let mut w = vec![0.0; v.len()];
    reg.prox_into(&v, &mut w);
    primal_reg(data, &w, lambda, reg, loss) - dual_reg(data, alpha, lambda, reg, loss)
}

/// Reference optimum for the regularized problem: single-machine
/// permutation SDCA on the normalized subproblem with a leader-style prox
/// map between passes, until the regularized duality gap falls below
/// `tol`. Feeds the suboptimality axis of the sparsity-recovery figure.
pub fn compute_optimum_reg(
    data: &Dataset,
    lambda: f64,
    reg: &dyn Regularizer,
    loss: &dyn Loss,
    tol: f64,
    max_passes: usize,
) -> (f64, Vec<f64>) {
    use crate::solvers::{Block, ExactBlockSolver, LocalDualMethod};

    let n = data.n();
    let lambda_eff = lambda * reg.strong_convexity();
    let block = Block::new(data.clone(), lambda_eff * n as f64);
    let solver = ExactBlockSolver { tol: 0.0, max_passes: 1 };
    let mut alpha = vec![0.0; n];
    let mut v = vec![0.0; data.d()];
    let mut w = vec![0.0; data.d()];
    let mut rng = crate::util::Rng::seed_from_u64(0x0c0c0a);
    let mut best_primal = f64::INFINITY;
    for _ in 0..max_passes {
        let up = solver.local_update(&block, loss, &alpha, &w, n, &mut rng);
        for (a, da) in alpha.iter_mut().zip(&up.dalpha) {
            *a += da;
        }
        for (vv, dv) in v.iter_mut().zip(&up.dw) {
            *vv += dv;
        }
        reg.prox_into(&v, &mut w);
        let p = primal_reg(data, &w, lambda, reg, loss);
        let w_norm_sq: f64 = w.iter().map(|x| x * x).sum();
        let d = dual_from_partials(block_conj_sum(data, &alpha, loss), w_norm_sq, lambda_eff, n);
        best_primal = best_primal.min(p);
        if p - d < tol {
            break;
        }
    }
    (best_primal, w)
}

#[cfg(test)]
mod reg_tests {
    use super::*;
    use crate::data::cov_like;
    use crate::loss::Squared;
    use crate::regularizers::{RegularizerKind, L2};

    #[test]
    fn l2_reg_objectives_match_plain_bit_for_bit() {
        let data = cov_like(60, 6, 0.1, 5);
        let lambda = 0.07;
        let alpha: Vec<f64> = data.labels.iter().map(|y| 0.3 * y).collect();
        let w = data.primal_from_dual(&alpha, lambda);
        assert_eq!(
            primal_reg(&data, &w, lambda, &L2, &Squared).to_bits(),
            primal(&data, &w, lambda, &Squared).to_bits()
        );
        assert_eq!(
            dual_reg(&data, &alpha, lambda, &L2, &Squared).to_bits(),
            dual(&data, &alpha, lambda, &Squared).to_bits()
        );
        assert_eq!(
            duality_gap_reg(&data, &alpha, lambda, &L2, &Squared).to_bits(),
            duality_gap(&data, &alpha, lambda, &Squared).to_bits()
        );
    }

    #[test]
    fn regularized_gap_nonnegative_at_feasible_points() {
        let data = cov_like(70, 8, 0.1, 6);
        let lambda = 0.05;
        for kind in [
            RegularizerKind::L1 { epsilon: 0.5 },
            RegularizerKind::ElasticNet { l1_ratio: 0.4 },
        ] {
            let reg = kind.build();
            for scale in [0.0, 0.2, 0.7] {
                let alpha: Vec<f64> =
                    data.labels.iter().map(|y| scale * y).collect();
                let g = duality_gap_reg(&data, &alpha, lambda, reg.as_ref(), &Squared);
                assert!(g >= -1e-10, "{kind}: negative gap {g} at scale {scale}");
            }
        }
    }

    #[test]
    fn compute_optimum_reg_matches_lasso_closed_form_on_orthogonal_design() {
        // Design and formula deliberately re-derived inline rather than
        // through experiments::sparsity::{lasso_design, lasso_closed_form}
        // — this test is the independent cross-check those helpers (and
        // the golden-lasso suite built on them) are validated against.
        //
        // Orthogonal design: d columns, m rows per column, each row the
        // column's indicator (X^T X = m I). Per coordinate the smoothed
        // lasso optimum is closed-form:
        //   w_j* = soft(z_j / n, lambda) / (lambda*eps + m/n),  z_j = m y_j
        // (the prox threshold in primal units is exactly lambda for the
        // epsilon-smoothed L1 — see `regularizers::SmoothedL1`).
        let (d, m) = (4usize, 10usize);
        let n = d * m;
        let y_col = [0.9, -0.6, 0.05, -0.02]; // two active, two thresholded
        let mut triplets = Vec::new();
        let mut labels = Vec::with_capacity(n);
        for j in 0..d {
            for r in 0..m {
                triplets.push((j * m + r, j as u32, 1.0));
                labels.push(y_col[j]);
            }
        }
        let features = crate::data::Features::Sparse(
            crate::data::CsrMatrix::from_triplets(n, d, &triplets),
        );
        let data = Dataset::new(features, labels);

        let (lambda, eps) = (0.1, 0.5);
        let reg = RegularizerKind::L1 { epsilon: eps }.build();
        // tol 0: run the full pass budget — a gap of 1e-12 would only
        // certify |w - w*| ~ 1e-6 (quadratic relation), but the iterate
        // itself converges geometrically to the f64 floor
        let (p_star, w_star) =
            compute_optimum_reg(&data, lambda, reg.as_ref(), &Squared, 0.0, 4000);

        let c = m as f64 / n as f64;
        for j in 0..d {
            let z = m as f64 * y_col[j] / n as f64;
            let expect = crate::regularizers::soft_threshold(z, lambda) / (lambda * eps + c);
            assert!(
                (w_star[j] - expect).abs() < 1e-8,
                "w[{j}] = {} vs closed form {expect}",
                w_star[j]
            );
        }
        // exact support recovery: the two weak columns are *exactly* zero
        assert_eq!(w_star[2], 0.0);
        assert_eq!(w_star[3], 0.0);
        assert!(w_star[0] > 0.0 && w_star[1] < 0.0);
        // and the closed-form point's primal matches the reported optimum
        let expect_w: Vec<f64> = (0..d)
            .map(|j| {
                let z = m as f64 * y_col[j] / n as f64;
                crate::regularizers::soft_threshold(z, lambda) / (lambda * eps + c)
            })
            .collect();
        let p_closed = primal_reg(&data, &expect_w, lambda, reg.as_ref(), &Squared);
        assert!((p_star - p_closed).abs() < 1e-10, "{p_star} vs {p_closed}");
    }
}

// ---------------------------------------------------------------------------
// Local (per-block) duality structure — Appendix B of the paper.
//
// For block k with local data A_[k], local duals alpha_[k], and
// `w_bar = w - A_[k] alpha_[k]` (the other blocks' contribution), the paper
// defines a local primal/dual pair (eqs. (8)/(9)) whose gap certifies the
// *block* suboptimality — the quantity Assumption 1 contracts. Used by the
// gap-certified local solver and by tests of Proposition 4.

/// `P_k(w_k; w_bar)` of eq. (9), evaluated at `w_k = A_[k] alpha_[k]`.
/// `w` is the full shared vector (= w_bar + w_k), `n` the GLOBAL n.
pub fn local_primal(
    block: &Dataset,
    w: &[f64],
    w_k: &[f64],
    lambda: f64,
    n: usize,
    loss: &dyn Loss,
) -> f64 {
    let loss_sum = block_loss_sum(block, w, loss);
    let wk_norm_sq: f64 = w_k.iter().map(|v| v * v).sum();
    loss_sum / n as f64 + 0.5 * lambda * wk_norm_sq
}

/// `D_k(alpha_[k]; w_bar)` of eq. (8).
pub fn local_dual(
    block: &Dataset,
    alpha_k: &[f64],
    w: &[f64],
    w_k: &[f64],
    lambda: f64,
    n: usize,
    loss: &dyn Loss,
) -> f64 {
    let conj_sum = block_conj_sum(block, alpha_k, loss);
    let w_norm_sq: f64 = w.iter().map(|v| v * v).sum();
    let wbar_norm_sq: f64 = w
        .iter()
        .zip(w_k)
        .map(|(a, b)| (a - b) * (a - b))
        .sum();
    -0.5 * lambda * w_norm_sq + 0.5 * lambda * wbar_norm_sq - conj_sum / n as f64
}

/// The block's duality gap `g_k = P_k - D_k >= 0`; zero exactly at the
/// block optimum (strong duality of the local pair, Proposition 4).
pub fn local_gap(
    block: &Dataset,
    alpha_k: &[f64],
    w: &[f64],
    lambda: f64,
    n: usize,
    loss: &dyn Loss,
) -> f64 {
    // w_k = A_[k] alpha_[k] with the global 1/(lambda n) scaling
    let mut w_k = vec![0.0; block.d()];
    let scale = 1.0 / (lambda * n as f64);
    for (i, &a) in alpha_k.iter().enumerate() {
        if a != 0.0 {
            block.features.add_row_scaled(i, a * scale, &mut w_k);
        }
    }
    local_primal(block, w, &w_k, lambda, n, loss)
        - local_dual(block, alpha_k, w, &w_k, lambda, n, loss)
}

#[cfg(test)]
mod local_gap_tests {
    use super::*;
    use crate::data::cov_like;
    use crate::loss::{Hinge, SmoothedHinge};
    use crate::solvers::{Block, ExactBlockSolver, LocalDualMethod};
    use crate::util::Rng;

    #[test]
    fn local_gap_nonnegative() {
        let data = cov_like(40, 6, 0.1, 31);
        let lambda = 0.05;
        let n = 80; // pretend this block is half of a larger problem
        let alpha: Vec<f64> = data.labels.iter().map(|y| 0.3 * y).collect();
        let mut w = data.primal_from_dual(&alpha, lambda);
        // w also carries some other-block contribution
        for (j, wv) in w.iter_mut().enumerate() {
            *wv = *wv * 0.5 + 0.01 * (j as f64).sin();
        }
        let g = local_gap(&data, &alpha, &w, lambda, n, &Hinge);
        assert!(g >= -1e-10, "local gap {g} < 0");
    }

    #[test]
    fn local_gap_zero_at_block_optimum() {
        let data = cov_like(30, 5, 0.1, 32);
        let n = 30;
        let lambda = 0.1;
        let loss = SmoothedHinge::new(0.5);
        let block = Block::new(data.clone(), lambda * n as f64);
        let solver = ExactBlockSolver { tol: 1e-12, max_passes: 3000 };
        let mut rng = Rng::seed_from_u64(33);
        let up = solver.local_update(
            &block, &loss, &vec![0.0; 30], &vec![0.0; 5], 0, &mut rng,
        );
        let g = local_gap(&data, &up.dalpha, &up.dw, lambda, n, &loss);
        assert!(g.abs() < 1e-6, "gap at block optimum: {g}");
    }

    #[test]
    fn local_gap_equals_global_gap_for_single_block() {
        // With K = 1, w_bar = 0 and the local pair IS the global pair.
        let data = cov_like(25, 4, 0.1, 34);
        let lambda = 0.08;
        let alpha: Vec<f64> = data.labels.iter().map(|y| 0.4 * y).collect();
        let w = data.primal_from_dual(&alpha, lambda);
        let lg = local_gap(&data, &alpha, &w, lambda, data.n(), &Hinge);
        let gg = duality_gap(&data, &alpha, lambda, &Hinge);
        assert!((lg - gg).abs() < 1e-10, "{lg} vs {gg}");
    }
}
