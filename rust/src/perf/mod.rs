//! Reproducible performance harness — `cocoa perf`.
//!
//! The repo's north star says "as fast as the hardware allows"; this
//! module is how that claim becomes a measured trajectory instead of a
//! slogan. [`run_all`] executes standardized workloads (dense ridge,
//! rcv1-density sparse logistic, smoothed-L1 lasso, each at K ∈ {1, 4})
//! and [`run_ooc`] adds the out-of-core `_ooc` family (mmap-shard
//! training with a per-workload `dataset_bytes` / `peak_rss_bytes`
//! band); [`run_serve`] adds the `serve_` scoring family (live-snapshot
//! batch prediction, `predictions_per_sec` + p99 latency); together they
//! emit a schema-versioned `BENCH_hotpath.json`: steps/sec, simulated
//! time to a 1e-3 duality gap, byte-exact wire bytes, and peak RSS.
//!
//! CI consumes the `--smoke` profile twice:
//!
//! * a *structural* gate — the [`schema`] validator checks that every
//!   field is present, every number finite, and cumulative round times
//!   monotone;
//! * a *regression* gate — [`gate::compare`] checks steps/sec,
//!   time-to-1e-3-gap, and peak RSS against the checked-in per-workload
//!   baseline (`benchmarks/BENCH_hotpath.json`) within a tolerance band
//!   sized for shared-runner noise, and writes a delta report saying
//!   exactly what was and wasn't compared.

pub mod gate;
pub mod schema;
mod workloads;

pub use gate::{compare, compare_files, compare_str, GateOutcome};
pub use schema::{parse, validate, validate_file, validate_str, Json, SchemaError};
pub use workloads::{
    run_all, run_ooc, run_serve, BenchReport, PerfProfile, WorkloadReport, SCHEMA_VERSION,
};
