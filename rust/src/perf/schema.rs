//! Schema validation for `BENCH_*.json` — the contract CI's perf smoke
//! gate enforces (fields present, numbers finite, round times monotone)
//! without ever timing-gating.
//!
//! The offline build carries no serde, so this module ships a minimal
//! recursive-descent JSON parser (objects, arrays, strings, numbers,
//! bools, null — everything the bench report emits) plus the validator
//! over the parsed tree.

use std::fmt;

use super::SCHEMA_VERSION;

/// A parsed JSON value (order-preserving objects).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (first occurrence).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }
}

/// Why a bench report failed to parse or validate.
#[derive(Debug, Clone, PartialEq)]
pub struct SchemaError {
    pub message: String,
}

impl fmt::Display for SchemaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "bench schema: {}", self.message)
    }
}

impl std::error::Error for SchemaError {}

fn err<T>(message: impl Into<String>) -> Result<T, SchemaError> {
    Err(SchemaError { message: message.into() })
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), SchemaError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            err(format!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, SchemaError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, SchemaError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, SchemaError> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| SchemaError { message: "non-utf8 number".into() })?;
        match text.parse::<f64>() {
            Ok(v) => Ok(Json::Num(v)),
            Err(_) => err(format!("bad number {text:?} at byte {start}")),
        }
    }

    fn string(&mut self) -> Result<String, SchemaError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return err("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| SchemaError {
                                        message: "non-utf8 \\u escape".into(),
                                    })?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| SchemaError { message: "bad \\u escape".into() })?;
                            // surrogate pairs unsupported (the report never emits them)
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => {
                            return err(format!("bad escape {:?}", other.map(|c| c as char)))
                        }
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes (UTF-8 passes through intact)
                    let start = self.pos;
                    while let Some(b) = self.peek() {
                        if b == b'"' || b == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| SchemaError { message: "non-utf8 string".into() })?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, SchemaError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => {
                    return err(format!(
                        "expected ',' or ']' at byte {}, found {:?}",
                        self.pos,
                        other.map(|c| c as char)
                    ))
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, SchemaError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let value = self.value()?;
            fields.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                other => {
                    return err(format!(
                        "expected ',' or '}}' at byte {}, found {:?}",
                        self.pos,
                        other.map(|c| c as char)
                    ))
                }
            }
        }
    }
}

/// Parse a JSON document (trailing whitespace allowed, nothing else).
pub fn parse(text: &str) -> Result<Json, SchemaError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return err(format!("trailing garbage at byte {}", p.pos));
    }
    Ok(v)
}

// ---------------------------------------------------------------------------
// Validator
// ---------------------------------------------------------------------------

fn finite_num(doc: &Json, ctx: &str, key: &str) -> Result<f64, SchemaError> {
    match doc.get(key) {
        Some(Json::Num(v)) if v.is_finite() => Ok(*v),
        Some(Json::Num(v)) => err(format!("{ctx}: field {key:?} is not finite ({v})")),
        Some(_) => err(format!("{ctx}: field {key:?} is not a number")),
        None => err(format!("{ctx}: missing field {key:?}")),
    }
}

/// A finite number or an explicit null (targets that were never reached,
/// platforms without procfs).
fn finite_num_or_null(doc: &Json, ctx: &str, key: &str) -> Result<Option<f64>, SchemaError> {
    match doc.get(key) {
        Some(Json::Null) => Ok(None),
        _ => finite_num(doc, ctx, key).map(Some),
    }
}

/// Validate a bench report document against the `BENCH_*.json` schema.
/// Structural only: presence, types, finiteness, non-negativity where it
/// is meaningful, and monotone cumulative round times. Never compares
/// timings against thresholds — CI machines are too noisy for that.
pub fn validate(doc: &Json) -> Result<(), SchemaError> {
    let version = finite_num(doc, "report", "schema_version")?;
    if version != SCHEMA_VERSION as f64 {
        return err(format!(
            "schema_version {version} != supported {SCHEMA_VERSION}"
        ));
    }
    match doc.get("profile").and_then(Json::as_str) {
        Some("smoke") | Some("full") => {}
        Some(other) => return err(format!("unknown profile {other:?}")),
        None => return err("missing string field \"profile\""),
    }
    finite_num(doc, "report", "seed")?;
    match doc.get("kernel_backend").and_then(Json::as_str) {
        Some(s) if !s.is_empty() => {}
        Some(_) => return err("kernel_backend is empty"),
        None => return err("missing string field \"kernel_backend\""),
    }
    finite_num_or_null(doc, "report", "peak_rss_bytes")?;

    let workloads = match doc.get("workloads").and_then(Json::as_arr) {
        Some(w) if !w.is_empty() => w,
        Some(_) => return err("workloads array is empty"),
        None => return err("missing array field \"workloads\""),
    };
    for wl in workloads {
        let name = wl
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| SchemaError { message: "workload missing \"name\"".into() })?
            .to_string();
        let ctx = format!("workload {name:?}");
        for key in ["k", "threads", "n", "d", "rounds"] {
            let v = finite_num(wl, &ctx, key)?;
            if v < 1.0 {
                return err(format!("{ctx}: {key} = {v} < 1"));
            }
        }
        let density = finite_num(wl, &ctx, "density")?;
        if !(0.0..=1.0).contains(&density) {
            return err(format!("{ctx}: density {density} outside [0, 1]"));
        }
        for key in ["inner_steps", "wall_s", "steps_per_sec", "bytes_measured"] {
            let v = finite_num(wl, &ctx, key)?;
            if v < 0.0 {
                return err(format!("{ctx}: {key} = {v} < 0"));
            }
        }
        finite_num(wl, &ctx, "final_gap")?;
        // v3: per-phase wall seconds — exactly the five round phases,
        // each finite and nonnegative
        let phases = match wl.get("phase_seconds") {
            Some(p @ Json::Obj(fields)) => {
                if fields.len() != 5 {
                    return err(format!(
                        "{ctx}: phase_seconds has {} fields, expected 5",
                        fields.len()
                    ));
                }
                p
            }
            Some(_) => return err(format!("{ctx}: \"phase_seconds\" is not an object")),
            None => return err(format!("{ctx}: missing object \"phase_seconds\"")),
        };
        for key in ["broadcast", "local_solve", "reduce", "commit", "evaluate"] {
            let v = finite_num(phases, &format!("{ctx} phase_seconds"), key)?;
            if v < 0.0 {
                return err(format!("{ctx}: phase_seconds.{key} = {v} < 0"));
            }
        }
        if let Some(t) = finite_num_or_null(wl, &ctx, "time_to_gap_1e3_s")? {
            if t < 0.0 {
                return err(format!("{ctx}: time_to_gap_1e3_s = {t} < 0"));
            }
        }
        // v4: the out-of-core band. In-memory workloads record null for
        // both; an `_ooc` workload records the shard set's on-disk bytes
        // and the run's peak RSS, and the report is only valid if the
        // footprint stayed at least 2x below the data — the structural
        // proof that mmap-shard training is actually out-of-core.
        let dataset_bytes = finite_num_or_null(wl, &ctx, "dataset_bytes")?;
        let rss = finite_num_or_null(wl, &ctx, "peak_rss_bytes")?;
        for (key, v) in [("dataset_bytes", dataset_bytes), ("peak_rss_bytes", rss)] {
            if let Some(v) = v {
                if v < 0.0 {
                    return err(format!("{ctx}: {key} = {v} < 0"));
                }
            }
        }
        // v5: serving throughput. Null outside the `serve_` family; a
        // serve workload records predictions answered per second and the
        // 99th-percentile per-batch latency.
        for key in ["predictions_per_sec", "p99_latency_s"] {
            if let Some(v) = finite_num_or_null(wl, &ctx, key)? {
                if v < 0.0 {
                    return err(format!("{ctx}: {key} = {v} < 0"));
                }
            }
        }
        if let (Some(ds), Some(rss)) = (dataset_bytes, rss) {
            if rss * 2.0 > ds {
                return err(format!(
                    "{ctx}: out-of-core band violated — peak_rss_bytes {rss:.0} * 2 > \
                     dataset_bytes {ds:.0} (the run's footprint must stay at least 2x \
                     below the on-disk data)"
                ));
            }
        }
        let times = wl
            .get("round_sim_time_s")
            .and_then(Json::as_arr)
            .ok_or_else(|| SchemaError {
                message: format!("{ctx}: missing array \"round_sim_time_s\""),
            })?;
        if times.is_empty() {
            // the writer records at least round 0 — an empty trajectory
            // means the trace path broke, which is exactly what this gate
            // exists to catch
            return err(format!("{ctx}: round_sim_time_s is empty"));
        }
        let mut prev = f64::NEG_INFINITY;
        for (i, t) in times.iter().enumerate() {
            let v = match t.as_f64() {
                Some(v) if v.is_finite() => v,
                _ => return err(format!("{ctx}: round_sim_time_s[{i}] not a finite number")),
            };
            if v < prev {
                return err(format!(
                    "{ctx}: round_sim_time_s not monotone at index {i} ({prev} -> {v})"
                ));
            }
            prev = v;
        }
    }
    Ok(())
}

/// Parse + validate a report string.
pub fn validate_str(text: &str) -> Result<(), SchemaError> {
    validate(&parse(text)?)
}

/// Parse + validate a report file.
pub fn validate_file(path: &std::path::Path) -> Result<(), SchemaError> {
    let text = std::fs::read_to_string(path).map_err(|e| SchemaError {
        message: format!("read {}: {e}", path.display()),
    })?;
    validate_str(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parser_roundtrips_basic_values() {
        let doc = parse(r#"{"a": 1.5, "b": [1, 2, null], "c": "x\ny", "d": true}"#).unwrap();
        assert_eq!(doc.get("a").unwrap().as_f64(), Some(1.5));
        assert_eq!(doc.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(doc.get("c").unwrap().as_str(), Some("x\ny"));
        assert_eq!(doc.get("d"), Some(&Json::Bool(true)));
        assert!(parse("{\"a\": }").is_err());
        assert!(parse("[1, 2").is_err());
        assert!(parse("{} trailing").is_err());
    }

    fn minimal_workload(extra: &str, times: &str) -> String {
        format!(
            r#"{{"schema_version": 5, "profile": "smoke", "seed": 7,
                "kernel_backend": "scalar",
                "peak_rss_bytes": 1048576,
                "workloads": [{{"name": "w", "k": 1, "threads": 1, "n": 10, "d": 2,
                  "density": 1.0, "rounds": 3, "inner_steps": 30,
                  "wall_s": 0.01, "steps_per_sec": 3000.0,
                  "final_gap": 0.5, "time_to_gap_1e3_s": null,
                  "bytes_measured": 128,
                  "dataset_bytes": null, "peak_rss_bytes": null,
                  "predictions_per_sec": null, "p99_latency_s": null,
                  "phase_seconds": {{"broadcast": 0.001, "local_solve": 0.006,
                    "reduce": 0.002, "commit": 0.0005, "evaluate": 0.0005}},
                  "round_sim_time_s": {times}{extra}}}]}}"#
        )
    }

    #[test]
    fn validator_accepts_a_wellformed_report() {
        validate_str(&minimal_workload("", "[0.0, 0.1, 0.1, 0.4]")).unwrap();
    }

    #[test]
    fn validator_rejects_non_monotone_round_times() {
        let e = validate_str(&minimal_workload("", "[0.0, 0.5, 0.2]")).unwrap_err();
        assert!(e.message.contains("not monotone"), "{e}");
    }

    #[test]
    fn validator_rejects_empty_round_times() {
        let e = validate_str(&minimal_workload("", "[]")).unwrap_err();
        assert!(e.message.contains("empty"), "{e}");
    }

    #[test]
    fn validator_rejects_missing_fields_and_bad_version() {
        let doc = minimal_workload("", "[0.0]").replace("\"schema_version\": 5", "\"schema_version\": 99");
        assert!(validate_str(&doc).unwrap_err().message.contains("schema_version"));
        let doc = minimal_workload("", "[0.0]").replace("\"steps_per_sec\": 3000.0,", "");
        assert!(validate_str(&doc)
            .unwrap_err()
            .message
            .contains("steps_per_sec"));
        let doc = minimal_workload("", "[0.0]").replace("\"kernel_backend\": \"scalar\",", "");
        assert!(validate_str(&doc).unwrap_err().message.contains("kernel_backend"));
        let doc = minimal_workload("", "[0.0]").replace("\"threads\": 1,", "\"threads\": 0,");
        assert!(validate_str(&doc).unwrap_err().message.contains("threads"));
    }

    #[test]
    fn validator_rejects_bad_phase_seconds() {
        let doc = minimal_workload("", "[0.0]").replace("\"broadcast\": 0.001,", "");
        assert!(validate_str(&doc).unwrap_err().message.contains("expected 5"));
        let doc = minimal_workload("", "[0.0]")
            .replace("\"local_solve\": 0.006,", "\"local_solve\": -0.006,");
        assert!(validate_str(&doc).unwrap_err().message.contains("local_solve"));
        let doc = minimal_workload("", "[0.0]")
            .replace("\"reduce\": 0.002,", "\"warp\": 0.002,");
        assert!(validate_str(&doc).unwrap_err().message.contains("reduce"));
    }

    #[test]
    fn validator_enforces_the_out_of_core_band() {
        // both fields recorded and RSS well under half the data: valid
        let ok = minimal_workload("", "[0.0]").replace(
            "\"dataset_bytes\": null, \"peak_rss_bytes\": null",
            "\"dataset_bytes\": 100000000, \"peak_rss_bytes\": 40000000",
        );
        validate_str(&ok).unwrap();
        // footprint above half the data: the band is violated
        let fat = minimal_workload("", "[0.0]").replace(
            "\"dataset_bytes\": null, \"peak_rss_bytes\": null",
            "\"dataset_bytes\": 100000000, \"peak_rss_bytes\": 60000000",
        );
        let e = validate_str(&fat).unwrap_err();
        assert!(e.message.contains("out-of-core band"), "{e}");
        // dropping the fields entirely is a schema error, not a skip —
        // v4 reports must state them (null means "in-memory workload")
        let missing = minimal_workload("", "[0.0]")
            .replace("\"dataset_bytes\": null, \"peak_rss_bytes\": null,", "");
        let e = validate_str(&missing).unwrap_err();
        assert!(e.message.contains("dataset_bytes"), "{e}");
    }

    #[test]
    fn validator_checks_the_serve_fields() {
        // a serve workload records both numbers
        let serve = minimal_workload("", "[0.0]").replace(
            "\"predictions_per_sec\": null, \"p99_latency_s\": null",
            "\"predictions_per_sec\": 120000.0, \"p99_latency_s\": 0.002",
        );
        validate_str(&serve).unwrap();
        // negative throughput is nonsense
        let neg = minimal_workload("", "[0.0]").replace(
            "\"predictions_per_sec\": null",
            "\"predictions_per_sec\": -1.0",
        );
        let e = validate_str(&neg).unwrap_err();
        assert!(e.message.contains("predictions_per_sec"), "{e}");
        // v5 reports must state the fields even for non-serve workloads
        let missing = minimal_workload("", "[0.0]")
            .replace("\"predictions_per_sec\": null, \"p99_latency_s\": null,", "");
        let e = validate_str(&missing).unwrap_err();
        assert!(e.message.contains("predictions_per_sec"), "{e}");
    }

    #[test]
    fn validator_rejects_non_finite_numbers() {
        // 1e999 overflows to inf when parsed — must be rejected, JSON has
        // no way to express it intentionally
        let doc = minimal_workload("", "[0.0]").replace("\"wall_s\": 0.01", "\"wall_s\": 1e999");
        assert!(validate_str(&doc).unwrap_err().message.contains("wall_s"));
    }
}
