//! The perf-regression gate behind `cocoa perf --validate --baseline`:
//! compare a candidate `BENCH_*.json` against a checked-in per-workload
//! baseline within a tolerance band, and say *exactly* what was and
//! wasn't checked.
//!
//! Three comparisons, all relative to `tolerance` (a fraction; 0.5 means
//! "within 50%", sized for shared-runner noise):
//!
//! * `steps_per_sec` per workload — candidate must reach at least
//!   `(1 - tolerance) x baseline`;
//! * `time_to_gap_1e3_s` per workload — candidate must be at most
//!   `(1 + tolerance) x baseline`. A `null` baseline (target never
//!   reached) skips the check; a `null` candidate against a non-null
//!   baseline is a regression (the build stopped reaching the gap);
//! * `peak_rss_bytes` per report — candidate at most
//!   `(1 + tolerance) x baseline`, same null rules;
//! * `phase_seconds.<phase>` per workload (v3) — candidate at most
//!   `(1 + tolerance) x baseline` for each round phase, so a failure
//!   names *which phase* regressed. A zero baseline phase is skipped
//!   (noise would dominate a ratio against ~0);
//! * `peak_rss_bytes` per workload (v4, the `_ooc` out-of-core family)
//!   — candidate at most `(1 + tolerance) x baseline`, same null rules,
//!   so a footprint regression names the workload that fattened (the
//!   hard `rss * 2 <= dataset_bytes` band is the validator's job; this
//!   comparison catches drift long before the band breaks);
//! * `predictions_per_sec` per workload (v5, the `serve_` scoring
//!   family) — candidate must reach at least `(1 - tolerance) x
//!   baseline`, same null rules as time-to-gap (a null baseline skips, a
//!   candidate that stopped reporting throughput fails);
//! * `p99_latency_s` per workload (v5) — candidate at most
//!   `(1 + tolerance) x baseline`, same null rules.
//!
//! Workloads present in the baseline but missing from the candidate fail
//! the gate (a silently dropped workload is how a regression hides);
//! candidate workloads the baseline does not know are reported as
//! unchecked, not failed, so baselines can lag new workloads.
//!
//! A *negative* tolerance tightens the gate past equality: `--tolerance
//! -1` demands `steps_per_sec >= 2x` the baseline's, which no run
//! satisfies against itself — CI uses that as a self-test that the gate
//! can actually fail (see `ci.sh`).

use super::schema::{parse, validate, Json, SchemaError};

fn err<T>(message: impl Into<String>) -> Result<T, SchemaError> {
    Err(SchemaError { message: message.into() })
}

/// The gate's full verdict: every failed comparison, every comparison
/// that ran, and every comparison that was skipped (with the reason).
#[derive(Debug, Clone)]
pub struct GateOutcome {
    /// The tolerance band the comparisons used.
    pub tolerance: f64,
    /// Human-readable failures; empty means the gate passed.
    pub failures: Vec<String>,
    /// Comparisons that ran, with the measured ratios.
    pub checked: Vec<String>,
    /// Comparisons that could not run and why (null baselines, workloads
    /// unknown to the baseline).
    pub skipped: Vec<String>,
}

impl GateOutcome {
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }

    /// The delta report: verdict, then what was checked, skipped, and
    /// failed — written next to the bench JSON for the CI artifact.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "perf gate: {} (tolerance {:+.0}%)\n",
            if self.passed() { "PASS" } else { "FAIL" },
            self.tolerance * 100.0
        ));
        for line in &self.checked {
            s.push_str(&format!("  checked  {line}\n"));
        }
        for line in &self.skipped {
            s.push_str(&format!("  skipped  {line}\n"));
        }
        for line in &self.failures {
            s.push_str(&format!("  FAILED   {line}\n"));
        }
        s
    }
}

fn workload_map(doc: &Json, which: &str) -> Result<Vec<(String, Json)>, SchemaError> {
    let arr = doc
        .get("workloads")
        .and_then(Json::as_arr)
        .ok_or_else(|| SchemaError { message: format!("{which}: missing workloads") })?;
    arr.iter()
        .map(|w| {
            let name = w
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| SchemaError { message: format!("{which}: unnamed workload") })?;
            Ok((name.to_string(), w.clone()))
        })
        .collect()
}

fn num(w: &Json, key: &str) -> Option<f64> {
    w.get(key).and_then(Json::as_f64)
}

/// `Some(Some(x))` for a number, `Some(None)` for an explicit null,
/// `None` for a missing or mistyped field.
fn opt_num(w: &Json, key: &str) -> Option<Option<f64>> {
    match w.get(key) {
        Some(Json::Null) => Some(None),
        Some(v) => v.as_f64().map(Some),
        None => None,
    }
}

/// Compare a candidate report against a baseline report. Both documents
/// must individually pass [`validate`] first — this function re-checks
/// that so a gate invocation can never silently compare garbage.
pub fn compare(candidate: &Json, baseline: &Json, tolerance: f64) -> Result<GateOutcome, SchemaError> {
    if !tolerance.is_finite() {
        return err(format!("tolerance must be finite, got {tolerance}"));
    }
    validate(candidate).map_err(|e| SchemaError { message: format!("candidate: {}", e.message) })?;
    validate(baseline).map_err(|e| SchemaError { message: format!("baseline: {}", e.message) })?;

    let mut out = GateOutcome {
        tolerance,
        failures: Vec::new(),
        checked: Vec::new(),
        skipped: Vec::new(),
    };

    let cand = workload_map(candidate, "candidate")?;
    let base = workload_map(baseline, "baseline")?;

    for (name, bw) in &base {
        let Some((_, cw)) = cand.iter().find(|(n, _)| n == name) else {
            out.failures.push(format!(
                "{name}: present in the baseline but missing from the candidate"
            ));
            continue;
        };

        // throughput: the headline number, always gated
        let b_sps = num(bw, "steps_per_sec").unwrap_or(f64::NAN);
        let c_sps = num(cw, "steps_per_sec").unwrap_or(f64::NAN);
        let floor = (1.0 - tolerance) * b_sps;
        let line = format!(
            "{name}: steps_per_sec {c_sps:.1} vs baseline {b_sps:.1} (floor {floor:.1})"
        );
        if c_sps >= floor {
            out.checked.push(line);
        } else {
            out.failures.push(line);
        }

        // time to the 1e-3 gap: only when the baseline reached it
        match (opt_num(bw, "time_to_gap_1e3_s"), opt_num(cw, "time_to_gap_1e3_s")) {
            (Some(None), _) => out.skipped.push(format!(
                "{name}: time_to_gap_1e3_s (baseline never reached the gap)"
            )),
            (Some(Some(b_t)), Some(Some(c_t))) => {
                let ceil = (1.0 + tolerance) * b_t;
                let line = format!(
                    "{name}: time_to_gap_1e3_s {c_t:.4} vs baseline {b_t:.4} (ceiling {ceil:.4})"
                );
                if c_t <= ceil {
                    out.checked.push(line);
                } else {
                    out.failures.push(line);
                }
            }
            (Some(Some(b_t)), Some(None)) => out.failures.push(format!(
                "{name}: baseline reached the 1e-3 gap in {b_t:.4}s, candidate never did"
            )),
            _ => out.failures.push(format!("{name}: time_to_gap_1e3_s missing")),
        }

        // per-workload peak RSS (v4, the out-of-core family): drift in
        // the mmap path's footprint fails here long before it would
        // break the validator's hard 2x band
        match (opt_num(bw, "peak_rss_bytes"), opt_num(cw, "peak_rss_bytes")) {
            (Some(None), _) => out.skipped.push(format!(
                "{name}: peak_rss_bytes (baseline recorded none)"
            )),
            (Some(Some(b_r)), Some(Some(c_r))) => {
                let ceil = (1.0 + tolerance) * b_r;
                let line = format!(
                    "{name}: peak_rss_bytes {c_r:.0} vs baseline {b_r:.0} (ceiling {ceil:.0})"
                );
                if c_r <= ceil {
                    out.checked.push(line);
                } else {
                    out.failures.push(line);
                }
            }
            (Some(Some(b_r)), Some(None)) => out.failures.push(format!(
                "{name}: baseline recorded peak_rss_bytes {b_r:.0}, candidate recorded none"
            )),
            _ => out.failures.push(format!("{name}: peak_rss_bytes missing")),
        }

        // serving throughput (v5, the serve_ family): a floor, like
        // steps_per_sec — fewer predictions per second is the regression
        match (opt_num(bw, "predictions_per_sec"), opt_num(cw, "predictions_per_sec")) {
            (Some(None), _) => out.skipped.push(format!(
                "{name}: predictions_per_sec (baseline recorded none)"
            )),
            (Some(Some(b_p)), Some(Some(c_p))) => {
                let floor = (1.0 - tolerance) * b_p;
                let line = format!(
                    "{name}: predictions_per_sec {c_p:.1} vs baseline {b_p:.1} (floor {floor:.1})"
                );
                if c_p >= floor {
                    out.checked.push(line);
                } else {
                    out.failures.push(line);
                }
            }
            (Some(Some(b_p)), Some(None)) => out.failures.push(format!(
                "{name}: baseline recorded predictions_per_sec {b_p:.1}, candidate recorded none"
            )),
            _ => out.failures.push(format!("{name}: predictions_per_sec missing")),
        }

        // p99 scoring latency (v5): a ceiling — fatter tails fail
        match (opt_num(bw, "p99_latency_s"), opt_num(cw, "p99_latency_s")) {
            (Some(None), _) => out.skipped.push(format!(
                "{name}: p99_latency_s (baseline recorded none)"
            )),
            (Some(Some(b_l)), Some(Some(c_l))) => {
                let ceil = (1.0 + tolerance) * b_l;
                let line = format!(
                    "{name}: p99_latency_s {c_l:.6} vs baseline {b_l:.6} (ceiling {ceil:.6})"
                );
                if c_l <= ceil {
                    out.checked.push(line);
                } else {
                    out.failures.push(line);
                }
            }
            (Some(Some(b_l)), Some(None)) => out.failures.push(format!(
                "{name}: baseline recorded p99_latency_s {b_l:.6}, candidate recorded none"
            )),
            _ => out.failures.push(format!("{name}: p99_latency_s missing")),
        }

        // per-phase wall seconds: a failure here localizes the regression
        // to the phase that moved (broadcast / local_solve / reduce /
        // commit / evaluate)
        for phase in ["broadcast", "local_solve", "reduce", "commit", "evaluate"] {
            let b_p = bw.get("phase_seconds").and_then(|p| num(p, phase));
            let c_p = cw.get("phase_seconds").and_then(|p| num(p, phase));
            match (b_p, c_p) {
                (Some(b), Some(c)) => {
                    if b <= 0.0 {
                        out.skipped.push(format!(
                            "{name}: phase_seconds.{phase} (baseline recorded ~0)"
                        ));
                        continue;
                    }
                    let ceil = (1.0 + tolerance) * b;
                    let line = format!(
                        "{name}: phase_seconds.{phase} {c:.4} vs baseline {b:.4} \
                         (ceiling {ceil:.4})"
                    );
                    if c <= ceil {
                        out.checked.push(line);
                    } else {
                        out.failures.push(line);
                    }
                }
                _ => out
                    .failures
                    .push(format!("{name}: phase_seconds.{phase} missing")),
            }
        }
    }

    for (name, _) in &cand {
        if !base.iter().any(|(n, _)| n == name) {
            out.skipped.push(format!("{name}: not in the baseline (new workload, not gated)"));
        }
    }

    // peak RSS: report-level, same null semantics as time-to-gap
    match (
        opt_num(baseline, "peak_rss_bytes"),
        opt_num(candidate, "peak_rss_bytes"),
    ) {
        (Some(None), _) => out
            .skipped
            .push("peak_rss_bytes (baseline recorded none)".into()),
        (Some(Some(b)), Some(Some(c))) => {
            let ceil = (1.0 + tolerance) * b;
            let line = format!("report: peak_rss_bytes {c:.0} vs baseline {b:.0} (ceiling {ceil:.0})");
            if c <= ceil {
                out.checked.push(line);
            } else {
                out.failures.push(line);
            }
        }
        (Some(Some(b)), Some(None)) => out.failures.push(format!(
            "report: baseline recorded peak_rss_bytes {b:.0}, candidate recorded none"
        )),
        _ => out.failures.push("report: peak_rss_bytes missing".into()),
    }

    Ok(out)
}

/// Parse + compare two report strings.
pub fn compare_str(candidate: &str, baseline: &str, tolerance: f64) -> Result<GateOutcome, SchemaError> {
    compare(&parse(candidate)?, &parse(baseline)?, tolerance)
}

/// Parse + compare two report files.
pub fn compare_files(
    candidate: &std::path::Path,
    baseline: &std::path::Path,
    tolerance: f64,
) -> Result<GateOutcome, SchemaError> {
    let read = |p: &std::path::Path| {
        std::fs::read_to_string(p)
            .map_err(|e| SchemaError { message: format!("read {}: {e}", p.display()) })
    };
    compare_str(&read(candidate)?, &read(baseline)?, tolerance)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(name_sps: &[(&str, f64)], rss: &str, gap_s: &str) -> String {
        let workloads: Vec<String> = name_sps
            .iter()
            .map(|(name, sps)| {
                format!(
                    r#"{{"name": "{name}", "k": 1, "threads": 1, "n": 10, "d": 2,
                        "density": 1.0, "rounds": 3, "inner_steps": 30,
                        "wall_s": 0.01, "steps_per_sec": {sps},
                        "final_gap": 0.5, "time_to_gap_1e3_s": {gap_s},
                        "bytes_measured": 128,
                        "dataset_bytes": null, "peak_rss_bytes": null,
                        "predictions_per_sec": null, "p99_latency_s": null,
                        "phase_seconds": {{"broadcast": 0.001, "local_solve": 0.006,
                          "reduce": 0.002, "commit": 0.0005, "evaluate": 0.0005}},
                        "round_sim_time_s": [0.0, 0.1]}}"#
                )
            })
            .collect();
        format!(
            r#"{{"schema_version": 5, "profile": "smoke", "seed": 7,
                "kernel_backend": "scalar", "peak_rss_bytes": {rss},
                "workloads": [{}]}}"#,
            workloads.join(", ")
        )
    }

    #[test]
    fn identical_reports_pass_at_any_nonneg_tolerance() {
        let r = report(&[("a_k1", 1000.0), ("b_k1", 500.0)], "1048576", "0.2");
        for tol in [0.0, 0.1, 0.5] {
            let out = compare_str(&r, &r, tol).unwrap();
            assert!(out.passed(), "tol {tol}: {:?}", out.failures);
            assert!(!out.checked.is_empty());
        }
    }

    #[test]
    fn slower_candidate_fails_and_names_the_workload() {
        let base = report(&[("a_k1", 1000.0)], "1048576", "0.2");
        let slow = report(&[("a_k1", 400.0)], "1048576", "0.2");
        let out = compare_str(&slow, &base, 0.5).unwrap();
        assert!(!out.passed());
        assert!(out.failures[0].contains("a_k1"), "{:?}", out.failures);
        assert!(out.failures[0].contains("steps_per_sec"), "{:?}", out.failures);
        // within the band it passes: 600 >= (1 - 0.5) * 1000
        let ok = report(&[("a_k1", 600.0)], "1048576", "0.2");
        assert!(compare_str(&ok, &base, 0.5).unwrap().passed());
    }

    #[test]
    fn negative_tolerance_fails_a_self_comparison() {
        // the "gate actually gates" self-test CI runs: a report can never
        // be 2x faster than itself
        let r = report(&[("a_k1", 1000.0)], "1048576", "0.2");
        let out = compare_str(&r, &r, -1.0).unwrap();
        assert!(!out.passed());
    }

    #[test]
    fn missing_workload_fails_new_workload_skips() {
        let base = report(&[("a_k1", 1000.0), ("gone_k1", 10.0)], "1048576", "0.2");
        let cand = report(&[("a_k1", 1000.0), ("new_k1", 10.0)], "1048576", "0.2");
        let out = compare_str(&cand, &base, 0.5).unwrap();
        assert!(out.failures.iter().any(|f| f.contains("gone_k1")), "{:?}", out.failures);
        assert!(out.skipped.iter().any(|s| s.contains("new_k1")), "{:?}", out.skipped);
    }

    #[test]
    fn null_baseline_fields_skip_null_candidate_against_real_baseline_fails() {
        let base_null = report(&[("a_k1", 1000.0)], "null", "null");
        let cand = report(&[("a_k1", 1000.0)], "1048576", "0.2");
        let out = compare_str(&cand, &base_null, 0.5).unwrap();
        assert!(out.passed(), "{:?}", out.failures);
        assert!(out.skipped.iter().any(|s| s.contains("peak_rss_bytes")));
        assert!(out.skipped.iter().any(|s| s.contains("time_to_gap")));

        // the reverse direction is a regression, not a skip
        let out = compare_str(&base_null, &cand, 0.5).unwrap();
        assert!(!out.passed());
        assert!(out.failures.iter().any(|f| f.contains("peak_rss_bytes")), "{:?}", out.failures);
        assert!(out.failures.iter().any(|f| f.contains("1e-3 gap")), "{:?}", out.failures);
    }

    #[test]
    fn slower_time_to_gap_and_fatter_rss_fail() {
        let base = report(&[("a_k1", 1000.0)], "1000000", "0.2");
        let slow_gap = report(&[("a_k1", 1000.0)], "1000000", "0.9");
        let out = compare_str(&slow_gap, &base, 0.5).unwrap();
        assert!(out.failures.iter().any(|f| f.contains("time_to_gap")), "{:?}", out.failures);
        let fat = report(&[("a_k1", 1000.0)], "2000000", "0.2");
        let out = compare_str(&fat, &base, 0.5).unwrap();
        assert!(out.failures.iter().any(|f| f.contains("peak_rss_bytes")), "{:?}", out.failures);
    }

    #[test]
    fn phase_regression_names_the_phase_zero_baseline_phase_skips() {
        let base = report(&[("a_k1", 1000.0)], "1048576", "0.2");
        // one phase blows past the 50% band, the rest stay put
        let slow = base.replace("\"reduce\": 0.002", "\"reduce\": 0.02");
        let out = compare_str(&slow, &base, 0.5).unwrap();
        assert!(!out.passed());
        assert!(
            out.failures.iter().any(|f| f.contains("phase_seconds.reduce")),
            "{:?}",
            out.failures
        );
        assert!(
            !out.failures.iter().any(|f| f.contains("phase_seconds.commit")),
            "{:?}",
            out.failures
        );

        // a zero baseline phase is skipped, never failed
        let base_zero = base.replace("\"commit\": 0.0005", "\"commit\": 0.0");
        let out = compare_str(&base, &base_zero, 0.5).unwrap();
        assert!(out.passed(), "{:?}", out.failures);
        assert!(
            out.skipped.iter().any(|s| s.contains("phase_seconds.commit")),
            "{:?}",
            out.skipped
        );
    }

    #[test]
    fn per_workload_rss_gates_with_null_semantics() {
        // an _ooc-style workload with recorded footprint: growth past the
        // band fails and names the workload, within-band passes
        let with_rss = |rss: u64| {
            report(&[("rcv1_ooc_k2", 1000.0)], "1048576", "0.2").replace(
                "\"dataset_bytes\": null, \"peak_rss_bytes\": null",
                &format!("\"dataset_bytes\": 100000000, \"peak_rss_bytes\": {rss}"),
            )
        };
        let base = with_rss(10_000_000);
        let fat = with_rss(40_000_000);
        let out = compare_str(&fat, &base, 0.5).unwrap();
        assert!(
            out.failures
                .iter()
                .any(|f| f.contains("rcv1_ooc_k2") && f.contains("peak_rss_bytes")),
            "{:?}",
            out.failures
        );
        let ok = with_rss(12_000_000);
        assert!(compare_str(&ok, &base, 0.5).unwrap().passed());
        // a candidate that stopped recording its footprint is a
        // regression, not a skip
        let gone = report(&[("rcv1_ooc_k2", 1000.0)], "1048576", "0.2");
        let out = compare_str(&gone, &base, 0.5).unwrap();
        assert!(
            out.failures.iter().any(|f| f.contains("candidate recorded none")),
            "{:?}",
            out.failures
        );
    }

    #[test]
    fn serve_family_gates_throughput_floor_and_latency_ceiling() {
        let with_serve = |pps: f64, p99: &str| {
            report(&[("serve_sparse_k1", pps)], "1048576", "0.2").replace(
                "\"predictions_per_sec\": null, \"p99_latency_s\": null",
                &format!("\"predictions_per_sec\": {pps}, \"p99_latency_s\": {p99}"),
            )
        };
        let base = with_serve(100_000.0, "0.001");

        // throughput below the floor fails and names the field
        let slow = with_serve(40_000.0, "0.001");
        let out = compare_str(&slow, &base, 0.5).unwrap();
        assert!(
            out.failures.iter().any(|f| f.contains("predictions_per_sec")),
            "{:?}",
            out.failures
        );
        // a fatter p99 tail fails
        let fat = with_serve(100_000.0, "0.01");
        let out = compare_str(&fat, &base, 0.5).unwrap();
        assert!(
            out.failures.iter().any(|f| f.contains("p99_latency_s")),
            "{:?}",
            out.failures
        );
        // within the band both pass
        let ok = with_serve(60_000.0, "0.0012");
        assert!(compare_str(&ok, &base, 0.5).unwrap().passed());

        // null-p99 baseline skips the latency check but still gates
        // throughput; a candidate that stopped reporting throughput
        // against a recorded baseline fails
        let base_null_p99 = with_serve(100_000.0, "null");
        let out = compare_str(&with_serve(100_000.0, "0.5"), &base_null_p99, 0.5).unwrap();
        assert!(out.passed(), "{:?}", out.failures);
        assert!(out.skipped.iter().any(|s| s.contains("p99_latency_s")), "{:?}", out.skipped);
        let gone = report(&[("serve_sparse_k1", 100_000.0)], "1048576", "0.2");
        let out = compare_str(&gone, &base, 0.5).unwrap();
        assert!(
            out.failures
                .iter()
                .any(|f| f.contains("predictions_per_sec") && f.contains("recorded none")),
            "{:?}",
            out.failures
        );
    }

    #[test]
    fn garbage_documents_are_rejected_not_compared() {
        let good = report(&[("a_k1", 1000.0)], "1048576", "0.2");
        assert!(compare_str("{}", &good, 0.5).is_err());
        assert!(compare_str(&good, "{}", 0.5).is_err());
        assert!(compare_str(&good, &good, f64::NAN).is_err());
    }

    #[test]
    fn render_names_every_bucket() {
        let base = report(&[("a_k1", 1000.0), ("gone_k1", 10.0)], "null", "null");
        let cand = report(&[("a_k1", 1000.0), ("new_k1", 10.0)], "1048576", "0.2");
        let out = compare_str(&cand, &base, 0.5).unwrap();
        let text = out.render();
        assert!(text.contains("FAIL"), "{text}");
        assert!(text.contains("checked"), "{text}");
        assert!(text.contains("skipped"), "{text}");
        assert!(text.contains("gone_k1"), "{text}");
    }
}
