//! The standardized perf workloads behind `cocoa perf` — the repo's first
//! reproducible performance trajectory.
//!
//! Three workload families, each matched to a regime the paper's
//! experiments exercise, each run at K ∈ {1, 4}:
//!
//! * `dense_ridge` — cov-regime dense features, squared loss, L2 (the
//!   dense dot/axpy hot path);
//! * `sparse_logistic` — rcv1-regime CSR features at text-corpus density,
//!   logistic loss, L2 (the sparse gather/scatter hot path);
//! * `lasso_smoothed_l1` — squared loss with the ε-smoothed L1
//!   regularizer (the leader-side prox path and the sparse broadcast
//!   encoding).
//!
//! The `sparse_logistic` family additionally runs with `threads = 4`
//! (suffix `_t4`) so the intra-worker sharded hot path has its own
//! trajectory next to the sequential one.
//!
//! A fourth, out-of-core family (suffix `_ooc`, run by [`run_ooc`])
//! stream-generates rcv1/url/kdd-regime shard sets on disk and trains
//! from them via mmap. Those entries carry `dataset_bytes` and
//! `peak_rss_bytes`, and the schema validator enforces the band
//! `peak_rss_bytes * 2 <= dataset_bytes` — the checked-in proof that the
//! out-of-core path's footprint stays several times below the data.
//!
//! A fifth, serving family (prefix `serve_`, run by [`run_serve`])
//! trains briefly, publishes live snapshots through a
//! [`SnapshotSink`](crate::serve::SnapshotSink), and measures batched
//! scoring through [`Scorer`](crate::serve::Scorer) and
//! [`MulticlassScorer`](crate::serve::MulticlassScorer). Those entries
//! carry `predictions_per_sec` and `p99_latency_s` (null everywhere
//! else) and are gated like every other family.
//!
//! Every run uses the byte-exact counted transport and the ec2-like
//! network model, so `bytes_measured` and the simulated time axis are
//! populated. The report is written as schema-versioned JSON
//! (`BENCH_hotpath.json`) and validated by [`super::schema`]. CI runs the
//! `--smoke` profile as a structural gate, and `cocoa perf --validate
//! --baseline` compares steps/sec, time-to-gap, and peak RSS against a
//! checked-in per-workload baseline within a tolerance band (see
//! [`super::gate`]).

use std::io::Write;
use std::path::Path;
use std::time::Instant;

use crate::algorithms::Cocoa;
use crate::data::{
    cov_like, kdd_stream_shards, rcv1_like, rcv1_stream_shards, url_stream_shards, Dataset,
    ShardSet,
};
use crate::driver::{GapBelow, MaxRounds, StoppingRule};
use crate::loss::LossKind;
use crate::netsim::NetworkModel;
use crate::obs::{MetricsHub, Phase};
use crate::regularizers::RegularizerKind;
use crate::telemetry::{json_f64, peak_rss_bytes};
use crate::transport::TransportKind;
use crate::Trainer;

/// Version of the `BENCH_*.json` layout. Bump on any breaking change to
/// field names or meanings; the validator rejects mismatches.
/// v2: per-workload `threads`, top-level `kernel_backend`, `_t4` sparse
/// variants.
/// v3: per-workload `phase_seconds` (cumulative wall seconds per round
/// phase; `local_solve` is the slowest slot per round — the critical
/// path), so `perf --validate --baseline` localizes a regression to the
/// phase that moved. `peak_rss_bytes` now folds in the workers' maxima.
/// v4: per-workload `dataset_bytes` and `peak_rss_bytes` (both null
/// outside the `_ooc` out-of-core family); when both are present the
/// validator enforces the out-of-core band `peak_rss_bytes * 2 <=
/// dataset_bytes`, the report-level proof that mmap-shard training keeps
/// its footprint several times below the data it trains on.
/// v5: per-workload `predictions_per_sec` and `p99_latency_s` (both null
/// outside the `serve_` serving family) — the online-scoring trajectory
/// next to the training one.
pub const SCHEMA_VERSION: u32 = 5;

/// Problem sizes: tiny (CI smoke) or benchmark-scale.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PerfProfile {
    /// Seconds-scale total: structural gate for CI.
    Smoke,
    /// The real trajectory numbers.
    Full,
}

impl PerfProfile {
    pub fn as_str(self) -> &'static str {
        match self {
            PerfProfile::Smoke => "smoke",
            PerfProfile::Full => "full",
        }
    }
}

/// One workload's measurements.
#[derive(Debug, Clone)]
pub struct WorkloadReport {
    pub name: String,
    pub k: usize,
    /// Intra-worker shard count T the local solves ran with.
    pub threads: usize,
    pub n: usize,
    pub d: usize,
    pub density: f64,
    /// Outer rounds actually run.
    pub rounds: u64,
    /// Inner (coordinate) steps summed over workers.
    pub inner_steps: u64,
    /// Wall-clock seconds for the whole run (excludes session build).
    pub wall_s: f64,
    /// `inner_steps / wall_s` — the headline hot-path throughput.
    pub steps_per_sec: f64,
    /// Duality gap at the final evaluated round.
    pub final_gap: f64,
    /// Simulated seconds to reach gap <= 1e-3 (None if never reached).
    pub time_to_gap_1e3_s: Option<f64>,
    /// Byte-exact wire bytes (counted transport).
    pub bytes_measured: u64,
    /// On-disk bytes of the shard set an `_ooc` workload trained from
    /// (`None` for in-memory workloads).
    pub dataset_bytes: Option<u64>,
    /// Peak RSS observed over this workload's run (`None` for in-memory
    /// workloads and on platforms without procfs). The validator's
    /// out-of-core band requires `peak_rss_bytes * 2 <= dataset_bytes`
    /// whenever both are recorded.
    pub peak_rss_bytes: Option<u64>,
    /// Scoring throughput for the `serve_` family (`None` elsewhere):
    /// predictions answered per wall second through the live snapshot.
    pub predictions_per_sec: Option<f64>,
    /// 99th-percentile per-batch scoring latency in seconds (`None`
    /// outside the `serve_` family).
    pub p99_latency_s: Option<f64>,
    /// Cumulative wall seconds per round phase, indexed like
    /// [`Phase::ALL`] (`local_solve` = slowest slot per round).
    pub phase_seconds: [f64; 5],
    /// Cumulative simulated time at each evaluated round (monotone).
    pub round_sim_time_s: Vec<f64>,
}

/// The full bench report serialized to `BENCH_*.json`.
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub schema_version: u32,
    pub profile: PerfProfile,
    pub seed: u64,
    /// Which kernel backend the dispatcher picked on this machine
    /// (`scalar` / `avx2` / `neon`) — context for comparing steps/sec
    /// across runs.
    pub kernel_backend: String,
    pub peak_rss_bytes: Option<u64>,
    pub workloads: Vec<WorkloadReport>,
}

struct WorkloadSpec {
    name: &'static str,
    k: usize,
    threads: usize,
    data: Dataset,
    loss: LossKind,
    lambda: f64,
    regularizer: RegularizerKind,
    max_rounds: u64,
}

fn specs(profile: PerfProfile, seed: u64) -> Vec<WorkloadSpec> {
    // (n, d) per family; smoke shapes keep the whole suite in seconds
    let (ridge_n, ridge_d, sparse_n, sparse_d, sparse_nnz, lasso_n, lasso_d, cap) =
        match profile {
            PerfProfile::Smoke => (600, 24, 800, 2_000, 10, 400, 16, 20),
            PerfProfile::Full => (20_000, 54, 40_000, 20_000, 12, 4_000, 100, 200),
        };
    let mut specs = Vec::new();
    for k in [1usize, 4] {
        specs.push(WorkloadSpec {
            name: "dense_ridge",
            k,
            threads: 1,
            data: cov_like(ridge_n, ridge_d, 0.1, seed ^ 0xd0),
            loss: LossKind::Squared,
            lambda: 1.0 / ridge_n as f64,
            regularizer: RegularizerKind::L2,
            max_rounds: cap,
        });
        // the sparse hot path runs both sequential and T = 4 sharded, so
        // the intra-worker speedup is a first-class trajectory
        for threads in [1usize, 4] {
            specs.push(WorkloadSpec {
                name: "sparse_logistic",
                k,
                threads,
                data: rcv1_like(sparse_n, sparse_d, sparse_nnz, 0.1, seed ^ 0x5b),
                loss: LossKind::Logistic,
                lambda: 1.0 / sparse_n as f64,
                regularizer: RegularizerKind::L2,
                max_rounds: cap,
            });
        }
        specs.push(WorkloadSpec {
            name: "lasso_smoothed_l1",
            k,
            threads: 1,
            data: cov_like(lasso_n, lasso_d, 0.1, seed ^ 0x11),
            loss: LossKind::Squared,
            lambda: 0.05,
            regularizer: RegularizerKind::L1 { epsilon: 0.5 },
            max_rounds: cap,
        });
    }
    specs
}

/// Run every workload and assemble the report.
pub fn run_all(profile: PerfProfile, seed: u64) -> crate::Result<BenchReport> {
    let mut workloads = Vec::new();
    let mut worker_rss_max: u64 = 0;
    for spec in specs(profile, seed) {
        let n = spec.data.n();
        let d = spec.data.d();
        let density = spec.data.density();
        let h = (n / spec.k).max(1);
        let mut session = Trainer::on(&spec.data)
            .workers(spec.k)
            .loss(spec.loss)
            .lambda(spec.lambda)
            .regularizer(spec.regularizer)
            .network(NetworkModel::ec2_like())
            .transport(TransportKind::Counted)
            .seed(seed)
            .threads(spec.threads)
            .label(spec.name)
            .build()?;
        let stopping = GapBelow::new(1e-3).or(MaxRounds::new(spec.max_rounds));
        // spans feed the per-phase seconds of BENCH v3; the recorder costs
        // a few clock samples per round, well under measurement noise
        session.set_tracing(true);
        let hub = MetricsHub::new();
        let mut hub_obs = hub.observer();
        let t0 = Instant::now();
        let mut algorithm = Cocoa::new(h);
        let trace = {
            let mut driver = session.drive(&mut algorithm, stopping)?;
            driver.observe(&mut hub_obs)?;
            driver.drain()?
        };
        let wall_s = t0.elapsed().as_secs_f64();
        let stats = *session.stats();
        worker_rss_max = worker_rss_max.max(session.max_worker_rss());
        session.shutdown();

        let last = trace.rows.last().expect("at least round 0 recorded");
        let suffix = if spec.threads > 1 { format!("_t{}", spec.threads) } else { String::new() };
        workloads.push(WorkloadReport {
            name: format!("{}_k{}{}", spec.name, spec.k, suffix),
            k: spec.k,
            threads: spec.threads,
            n,
            d,
            density,
            rounds: stats.rounds.max(1),
            inner_steps: stats.inner_steps,
            wall_s,
            steps_per_sec: stats.inner_steps as f64 / wall_s.max(1e-9),
            final_gap: last.gap,
            time_to_gap_1e3_s: trace.time_to_gap(1e-3),
            bytes_measured: last.bytes_measured,
            dataset_bytes: None,
            peak_rss_bytes: None,
            predictions_per_sec: None,
            p99_latency_s: None,
            phase_seconds: hub.phase_seconds(),
            round_sim_time_s: trace.rows.iter().map(|r| r.sim_time_s).collect(),
        });
    }
    // run-wide max: the perf process itself, plus whatever the workers
    // reported in their metrics blocks (same process here, but the fold
    // is what a multi-process BENCH would need)
    let peak_rss = match peak_rss_bytes() {
        Some(rss) => Some(rss.max(worker_rss_max)),
        None if worker_rss_max > 0 => Some(worker_rss_max),
        None => None,
    };
    Ok(BenchReport {
        schema_version: SCHEMA_VERSION,
        profile,
        seed,
        kernel_backend: crate::kernels::backend_name().to_string(),
        peak_rss_bytes: peak_rss,
        workloads,
    })
}

/// One out-of-core workload: a streaming generator regime plus shapes
/// big enough that the RSS band is meaningful (the dataset must dwarf
/// the process footprint even at the smoke profile — a tiny shard set
/// would make `rss * 2 <= dataset_bytes` unsatisfiable by any
/// implementation).
struct OocSpec {
    name: &'static str,
    regime: fn(usize, usize, usize, u64, usize, &Path) -> crate::Result<ShardSet>,
    n: usize,
    d: usize,
    nnz_per_row: usize,
    k: usize,
}

fn ooc_specs(profile: PerfProfile) -> Vec<OocSpec> {
    fn rcv1(n: usize, d: usize, z: usize, s: u64, k: usize, p: &Path) -> crate::Result<ShardSet> {
        rcv1_stream_shards(n, d, z, s, k, p)
    }
    fn url(n: usize, d: usize, z: usize, s: u64, k: usize, p: &Path) -> crate::Result<ShardSet> {
        url_stream_shards(n, d, z, s, k, p)
    }
    fn kdd(n: usize, d: usize, z: usize, s: u64, k: usize, p: &Path) -> crate::Result<ShardSet> {
        kdd_stream_shards(n, d, z, s, k, p)
    }
    let mut specs = vec![OocSpec {
        name: "rcv1_ooc",
        regime: rcv1,
        n: 150_000,
        d: 40_000,
        nnz_per_row: 160,
        k: 2,
    }];
    if profile == PerfProfile::Full {
        specs.push(OocSpec {
            name: "url_ooc",
            regime: url,
            n: 250_000,
            d: 1_000_000,
            nnz_per_row: 120,
            k: 4,
        });
        specs.push(OocSpec {
            name: "kdd_ooc",
            regime: kdd,
            n: 600_000,
            d: 30_000,
            nnz_per_row: 50,
            k: 4,
        });
    }
    specs
}

/// Run the out-of-core workload family: stream-generate a shard set
/// under `dir` (never materializing the dataset in memory), train from
/// the mmapped shards, and record the on-disk dataset size next to the
/// run's peak RSS. The validator's v4 band (`rss * 2 <= dataset_bytes`)
/// then *proves* the footprint stayed several times below the data.
///
/// Kept separate from [`run_all`] because these workloads write hundreds
/// of megabytes to `dir` — the caller owns creating and cleaning it.
pub fn run_ooc(profile: PerfProfile, seed: u64, dir: &Path) -> crate::Result<Vec<WorkloadReport>> {
    let mut workloads = Vec::new();
    let cap = match profile {
        PerfProfile::Smoke => 3,
        PerfProfile::Full => 8,
    };
    for spec in ooc_specs(profile) {
        let subdir = dir.join(spec.name);
        let set = (spec.regime)(spec.n, spec.d, spec.nnz_per_row, seed, spec.k, &subdir)?;
        let dataset_bytes = set.total_bytes();
        let h = (set.n() / set.k()).max(1);
        let mut session = Trainer::on_shards(&set)
            .loss(LossKind::Logistic)
            .lambda(1.0 / set.n() as f64)
            .regularizer(RegularizerKind::L2)
            .network(NetworkModel::ec2_like())
            .transport(TransportKind::Counted)
            .seed(seed)
            .label(spec.name)
            .build()?;
        let stopping = GapBelow::new(1e-3).or(MaxRounds::new(cap));
        session.set_tracing(true);
        let hub = MetricsHub::new();
        let mut hub_obs = hub.observer();
        let t0 = Instant::now();
        let mut algorithm = Cocoa::new(h);
        let trace = {
            let mut driver = session.drive(&mut algorithm, stopping)?;
            driver.observe(&mut hub_obs)?;
            driver.drain()?
        };
        let wall_s = t0.elapsed().as_secs_f64();
        let stats = *session.stats();
        let worker_rss = session.max_worker_rss();
        session.shutdown();

        // the run's footprint: this process's lifetime peak folded with
        // whatever the workers reported (same process here, but the fold
        // is what a multi-process BENCH would need)
        let peak = match peak_rss_bytes() {
            Some(rss) => Some(rss.max(worker_rss)),
            None if worker_rss > 0 => Some(worker_rss),
            None => None,
        };
        let last = trace.rows.last().expect("at least round 0 recorded");
        workloads.push(WorkloadReport {
            name: format!("{}_k{}", spec.name, set.k()),
            k: set.k(),
            threads: 1,
            n: set.n(),
            d: set.d(),
            density: set.nnz() as f64 / (set.n() as f64 * set.d() as f64),
            rounds: stats.rounds.max(1),
            inner_steps: stats.inner_steps,
            wall_s,
            steps_per_sec: stats.inner_steps as f64 / wall_s.max(1e-9),
            final_gap: last.gap,
            time_to_gap_1e3_s: trace.time_to_gap(1e-3),
            bytes_measured: last.bytes_measured,
            dataset_bytes: Some(dataset_bytes),
            peak_rss_bytes: peak,
            predictions_per_sec: None,
            p99_latency_s: None,
            phase_seconds: hub.phase_seconds(),
            round_sim_time_s: trace.rows.iter().map(|r| r.sim_time_s).collect(),
        });
    }
    Ok(workloads)
}

/// Run the serving workload family: train a short session with a live
/// [`SnapshotSink`](crate::serve::SnapshotSink), then measure batched
/// scoring against the published snapshots.
///
/// * `serve_sparse_k1` — binary margins over rcv1-regime CSR batches
///   through [`Scorer::score_batch`](crate::serve::Scorer::score_batch)
///   (the fused sparse gather-dot path, re-reading the live handle per
///   batch exactly as `cocoa serve` does);
/// * `serve_multiclass_k1` — one-vs-rest `predict` over the same batches
///   through a [`MulticlassScorer`](crate::serve::MulticlassScorer)
///   built by `set_labels` + `reset` warm restarts of the same session.
///
/// Report mapping: `rounds` = batches scored, `inner_steps` = total
/// predictions, `steps_per_sec` = `predictions_per_sec`, and
/// `p99_latency_s` = 99th-percentile per-batch latency. Training fields
/// that do not apply (`final_gap`, `bytes_measured`, phase and sim-time
/// axes) are zero. Kept separate from [`run_all`] like [`run_ooc`]; the
/// `cocoa perf` driver merges all three.
pub fn run_serve(profile: PerfProfile, seed: u64) -> crate::Result<Vec<WorkloadReport>> {
    use crate::serve::{MulticlassScorer, Scorer, SnapshotSink};

    let (n, d, nnz, batches, rows, classes, rounds) = match profile {
        PerfProfile::Smoke => (400usize, 500usize, 8usize, 40usize, 64usize, 3usize, 5u64),
        PerfProfile::Full => (20_000, 20_000, 12, 400, 256, 8, 20),
    };
    let data = rcv1_like(n, d, nnz, 0.1, seed ^ 0x5e);
    let density = data.density();

    let mut session = Trainer::on(&data)
        .workers(1)
        .loss(LossKind::Hinge)
        .lambda(1.0 / n as f64)
        .regularizer(RegularizerKind::L2)
        .seed(seed)
        .label("serve_perf")
        .build()?;
    let mut sink = SnapshotSink::for_session(&session, 1);
    let handle = sink.handle();
    let mut algorithm = Cocoa::new(n.max(1));
    {
        let mut driver = session.drive(&mut algorithm, MaxRounds::new(rounds))?;
        driver.observe(&mut sink)?;
        driver.drain()?;
    }

    // rotating row windows over the dataset, materialized up front —
    // batch construction is the client's cost, not the serving path's
    let batch_feats: Vec<crate::data::Features> = (0..batches)
        .map(|b| {
            let rows: Vec<u32> =
                (0..rows).map(|r| ((b * rows + r) % n) as u32).collect();
            data.subset(&rows).features
        })
        .collect();

    // percentile over sorted per-batch latencies
    let p99_of = |lat: &mut Vec<f64>| {
        lat.sort_by(f64::total_cmp);
        let idx = ((lat.len() as f64 * 0.99).ceil() as usize).clamp(1, lat.len()) - 1;
        lat[idx]
    };
    let serve_report = |name: &str, total: u64, wall: f64, p99: f64| {
        let pps = total as f64 / wall.max(1e-9);
        WorkloadReport {
            name: name.to_string(),
            k: 1,
            threads: 1,
            n,
            d,
            density,
            rounds: batches as u64,
            inner_steps: total,
            wall_s: wall,
            steps_per_sec: pps,
            final_gap: 0.0,
            time_to_gap_1e3_s: None,
            bytes_measured: 0,
            dataset_bytes: None,
            peak_rss_bytes: None,
            predictions_per_sec: Some(pps),
            p99_latency_s: Some(p99),
            phase_seconds: [0.0; 5],
            round_sim_time_s: vec![0.0],
        }
    };

    let mut out = Vec::new();

    // serve_sparse: binary margins through the live handle
    let scorer = Scorer::live(handle.clone());
    let mut lat = Vec::with_capacity(batches);
    let mut total = 0u64;
    let t0 = Instant::now();
    for f in &batch_feats {
        let t = Instant::now();
        let scored = scorer.score_batch(f)?;
        lat.push(t.elapsed().as_secs_f64());
        total += scored.margins.len() as u64;
    }
    let wall = t0.elapsed().as_secs_f64();
    out.push(serve_report("serve_sparse_k1", total, wall, p99_of(&mut lat)));

    // serve_multiclass: one-vs-rest models from warm restarts of the
    // same session (curvatures are label-independent), then parallel
    // argmax scoring
    let mut models = Vec::with_capacity(classes);
    for c in 0..classes {
        let relabeled: Vec<f64> =
            (0..n).map(|i| if i % classes == c { 1.0 } else { -1.0 }).collect();
        session.set_labels(&relabeled)?;
        session.reset()?;
        let mut driver = session.drive(&mut algorithm, MaxRounds::new(rounds))?;
        driver.observe(&mut sink)?;
        driver.drain()?;
        models.push((*handle.current()).clone());
    }
    session.shutdown();
    let mc = MulticlassScorer::new(models)?;
    let mut lat = Vec::with_capacity(batches);
    let mut total = 0u64;
    let t0 = Instant::now();
    for f in &batch_feats {
        let t = Instant::now();
        let classes_out = mc.predict(f)?;
        lat.push(t.elapsed().as_secs_f64());
        total += classes_out.len() as u64;
    }
    let wall = t0.elapsed().as_secs_f64();
    out.push(serve_report("serve_multiclass_k1", total, wall, p99_of(&mut lat)));
    Ok(out)
}

impl BenchReport {
    /// Hand-rolled JSON (offline build: no serde), the exact layout
    /// [`super::schema::validate`] checks.
    pub fn to_json_string(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"schema_version\": {},\n", self.schema_version));
        s.push_str(&format!("  \"profile\": \"{}\",\n", self.profile.as_str()));
        s.push_str(&format!("  \"seed\": {},\n", self.seed));
        s.push_str(&format!("  \"kernel_backend\": \"{}\",\n", self.kernel_backend));
        s.push_str(&format!(
            "  \"peak_rss_bytes\": {},\n",
            self.peak_rss_bytes.map_or("null".to_string(), |v| v.to_string())
        ));
        s.push_str("  \"workloads\": [\n");
        for (i, w) in self.workloads.iter().enumerate() {
            let times: Vec<String> = w.round_sim_time_s.iter().map(|t| json_f64(*t)).collect();
            let phases: Vec<String> = Phase::ALL
                .iter()
                .map(|p| format!("\"{}\": {}", p.as_str(), json_f64(w.phase_seconds[p.index()])))
                .collect();
            s.push_str(&format!(
                "    {{\"name\": \"{}\", \"k\": {}, \"threads\": {}, \"n\": {}, \"d\": {}, \
                 \"density\": {}, \
                 \"rounds\": {}, \"inner_steps\": {}, \"wall_s\": {}, \"steps_per_sec\": {}, \
                 \"final_gap\": {}, \"time_to_gap_1e3_s\": {}, \"bytes_measured\": {}, \
                 \"dataset_bytes\": {}, \"peak_rss_bytes\": {}, \
                 \"predictions_per_sec\": {}, \"p99_latency_s\": {}, \
                 \"phase_seconds\": {{{}}}, \
                 \"round_sim_time_s\": [{}]}}{}\n",
                w.name,
                w.k,
                w.threads,
                w.n,
                w.d,
                json_f64(w.density),
                w.rounds,
                w.inner_steps,
                json_f64(w.wall_s),
                json_f64(w.steps_per_sec),
                json_f64(w.final_gap),
                w.time_to_gap_1e3_s.map_or("null".to_string(), json_f64),
                w.bytes_measured,
                w.dataset_bytes.map_or("null".to_string(), |v| v.to_string()),
                w.peak_rss_bytes.map_or("null".to_string(), |v| v.to_string()),
                w.predictions_per_sec.map_or("null".to_string(), json_f64),
                w.p99_latency_s.map_or("null".to_string(), json_f64),
                phases.join(", "),
                times.join(", "),
                if i + 1 == self.workloads.len() { "" } else { "," },
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Write the report, creating parent directories as needed.
    pub fn write<P: AsRef<Path>>(&self, path: P) -> std::io::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_json_string().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perf::schema;

    #[test]
    fn smoke_report_roundtrips_through_the_validator() {
        // the real end-to-end path CI runs: smoke workloads -> JSON ->
        // parse -> schema validation
        let report = run_all(PerfProfile::Smoke, 42).unwrap();
        // 3 families x K in {1, 4}, plus sparse_logistic at T = 4
        assert_eq!(report.workloads.len(), 8);
        assert!(!report.kernel_backend.is_empty());
        let names: Vec<&str> = report.workloads.iter().map(|w| w.name.as_str()).collect();
        assert!(names.contains(&"sparse_logistic_k4"), "{names:?}");
        assert!(names.contains(&"sparse_logistic_k4_t4"), "{names:?}");
        for w in &report.workloads {
            assert!(w.inner_steps > 0, "{}: no inner steps", w.name);
            assert!(w.bytes_measured > 0, "{}: counted transport silent", w.name);
            assert!(
                w.round_sim_time_s.windows(2).all(|p| p[1] >= p[0]),
                "{}: sim time not monotone",
                w.name
            );
            assert!(
                w.phase_seconds.iter().all(|s| s.is_finite() && *s >= 0.0),
                "{}: bad phase_seconds {:?}",
                w.name,
                w.phase_seconds
            );
            // real rounds ran, so the straggler barrier took real time
            assert!(
                w.phase_seconds[Phase::LocalSolve.index()] > 0.0,
                "{}: no local_solve time recorded",
                w.name
            );
        }
        let json = report.to_json_string();
        schema::validate_str(&json).unwrap();
    }

    #[test]
    fn serve_workloads_measure_scoring_and_validate() {
        let workloads = run_serve(PerfProfile::Smoke, 42).unwrap();
        assert_eq!(workloads.len(), 2);
        assert_eq!(workloads[0].name, "serve_sparse_k1");
        assert_eq!(workloads[1].name, "serve_multiclass_k1");
        for w in &workloads {
            let pps = w.predictions_per_sec.expect("serve family reports throughput");
            assert!(pps > 0.0, "{}: predictions_per_sec = {pps}", w.name);
            assert!((pps - w.steps_per_sec).abs() < 1e-9, "{}: steps_per_sec mirror", w.name);
            let p99 = w.p99_latency_s.expect("serve family reports p99");
            assert!(p99 >= 0.0 && p99.is_finite(), "{}: p99 = {p99}", w.name);
            assert!(w.inner_steps > 0, "{}: no predictions", w.name);
            assert_eq!(w.rounds as usize, 40, "{}: batch count", w.name);
        }
        // serve rows slot into a full report and still pass the validator
        let report = BenchReport {
            schema_version: SCHEMA_VERSION,
            profile: PerfProfile::Smoke,
            seed: 42,
            kernel_backend: crate::kernels::backend_name().to_string(),
            peak_rss_bytes: None,
            workloads,
        };
        schema::validate_str(&report.to_json_string()).unwrap();
    }

    #[test]
    fn report_write_creates_parents_and_validates() {
        let dir = std::env::temp_dir().join("cocoa_perf_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("nested/BENCH_test.json");
        let report = BenchReport {
            schema_version: SCHEMA_VERSION,
            profile: PerfProfile::Smoke,
            seed: 1,
            kernel_backend: "scalar".into(),
            peak_rss_bytes: None,
            workloads: vec![WorkloadReport {
                name: "w".into(),
                k: 1,
                threads: 1,
                n: 10,
                d: 2,
                density: 1.0,
                rounds: 2,
                inner_steps: 20,
                wall_s: 0.01,
                steps_per_sec: 2000.0,
                final_gap: 0.5,
                time_to_gap_1e3_s: None,
                bytes_measured: 64,
                dataset_bytes: None,
                peak_rss_bytes: None,
                predictions_per_sec: None,
                p99_latency_s: None,
                phase_seconds: [0.001, 0.008, 0.002, 0.0005, 0.0005],
                round_sim_time_s: vec![0.0, 0.5],
            }],
        };
        report.write(&path).unwrap();
        schema::validate_file(&path).unwrap();
    }
}
