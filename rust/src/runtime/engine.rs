//! The PJRT engine thread.
//!
//! `xla::PjRtClient` is `Rc`-based (not `Send`), so one dedicated thread
//! owns the client, the compiled executables, and the per-block data
//! literals; workers talk to it through a cloneable [`EngineHandle`] with
//! plain `Vec<f32>` payloads. Executables are compiled once per
//! (kernel, loss, shape) on first use; block feature matrices are uploaded
//! once at registration (data ships once on a real cluster too, so this is
//! not counted as round communication).

use std::collections::HashMap;

use std::sync::mpsc::{channel, Receiver, Sender};

use anyhow::{anyhow, Result};

use super::manifest::Manifest;

/// Output of one local_sdca execution (possibly chunked over cap).
#[derive(Debug, Clone)]
pub struct SdcaOut {
    pub dalpha: Vec<f32>,
    pub dw: Vec<f32>,
    /// Engine-side wall seconds spent in execute (the engine thread is
    /// dedicated, so wall ~= cpu there).
    pub compute_s: f64,
}

#[derive(Debug, Clone)]
pub struct EvalOut {
    pub loss_sum: f64,
    pub conj_sum: f64,
    pub compute_s: f64,
}

enum Request {
    Register {
        block_id: usize,
        x: Vec<f32>, // row-major n_k x d
        y: Vec<f32>,
        norms: Vec<f32>,
        n_k: usize,
        d: usize,
        reply: Sender<Result<(), String>>,
    },
    LocalSdca {
        block_id: usize,
        loss: String,
        alpha: Vec<f32>,
        w: Vec<f32>,
        idx: Vec<i32>,
        lam_n: f32,
        gamma: f32,
        reply: Sender<Result<SdcaOut, String>>,
    },
    Eval {
        block_id: usize,
        loss: String,
        alpha: Vec<f32>,
        w: Vec<f32>,
        gamma: f32,
        reply: Sender<Result<EvalOut, String>>,
    },
    Shutdown,
}

/// Cloneable handle workers use to reach the engine thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: Sender<Request>,
}

pub struct Engine {
    handle: EngineHandle,
    join: Option<std::thread::JoinHandle<()>>,
}

struct BlockData {
    x: xla::Literal, // f32[n_k, d]
    y: xla::Literal,
    norms: xla::Literal,
    n_k: usize,
    d: usize,
}

struct EngineState {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: std::path::PathBuf,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    blocks: HashMap<usize, BlockData>,
}

impl Engine {
    /// Spawn the engine thread over an artifacts directory.
    pub fn start(artifacts_dir: impl Into<std::path::PathBuf>) -> Result<Engine> {
        let dir = artifacts_dir.into();
        let manifest = Manifest::load(&dir)?;
        let (tx, rx) = channel::<Request>();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let join = std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || engine_main(dir, manifest, rx, ready_tx))?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))?
            .map_err(|e| anyhow!("PJRT client init failed: {e}"))?;
        Ok(Engine { handle: EngineHandle { tx }, join: Some(join) })
    }

    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl EngineHandle {
    /// Upload a block's static data (features, labels, norms) once.
    pub fn register_block(
        &self,
        block_id: usize,
        x: Vec<f32>,
        y: Vec<f32>,
        norms: Vec<f32>,
        n_k: usize,
        d: usize,
    ) -> Result<()> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Register { block_id, x, y, norms, n_k, d, reply })
            .map_err(|_| anyhow!("engine gone"))?;
        rx.recv().map_err(|_| anyhow!("engine gone"))?.map_err(|e| anyhow!(e))
    }

    /// Run H = idx.len() LocalSDCA steps on a registered block. The engine
    /// chunks over the artifact's idx capacity transparently.
    pub fn local_sdca(
        &self,
        block_id: usize,
        loss: &str,
        alpha: Vec<f32>,
        w: Vec<f32>,
        idx: Vec<i32>,
        lam_n: f32,
        gamma: f32,
    ) -> Result<SdcaOut> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::LocalSdca {
                block_id,
                loss: loss.to_string(),
                alpha,
                w,
                idx,
                lam_n,
                gamma,
                reply,
            })
            .map_err(|_| anyhow!("engine gone"))?;
        rx.recv().map_err(|_| anyhow!("engine gone"))?.map_err(|e| anyhow!(e))
    }

    /// Evaluate the block objective partial sums.
    pub fn eval(
        &self,
        block_id: usize,
        loss: &str,
        alpha: Vec<f32>,
        w: Vec<f32>,
        gamma: f32,
    ) -> Result<EvalOut> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Eval {
                block_id,
                loss: loss.to_string(),
                alpha,
                w,
                gamma,
                reply,
            })
            .map_err(|_| anyhow!("engine gone"))?;
        rx.recv().map_err(|_| anyhow!("engine gone"))?.map_err(|e| anyhow!(e))
    }
}

fn engine_main(
    dir: std::path::PathBuf,
    manifest: Manifest,
    rx: Receiver<Request>,
    ready: Sender<Result<(), String>>,
) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(e.to_string()));
            return;
        }
    };
    let mut st = EngineState {
        client,
        manifest,
        dir,
        executables: HashMap::new(),
        blocks: HashMap::new(),
    };
    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::Register { block_id, x, y, norms, n_k, d, reply } => {
                let r = register(&mut st, block_id, x, y, norms, n_k, d);
                let _ = reply.send(r.map_err(|e| e.to_string()));
            }
            Request::LocalSdca { block_id, loss, alpha, w, idx, lam_n, gamma, reply } => {
                let r = run_sdca(&mut st, block_id, &loss, alpha, w, idx, lam_n, gamma);
                let _ = reply.send(r.map_err(|e| e.to_string()));
            }
            Request::Eval { block_id, loss, alpha, w, gamma, reply } => {
                let r = run_eval(&mut st, block_id, &loss, alpha, w, gamma);
                let _ = reply.send(r.map_err(|e| e.to_string()));
            }
        }
    }
}

fn register(
    st: &mut EngineState,
    block_id: usize,
    x: Vec<f32>,
    y: Vec<f32>,
    norms: Vec<f32>,
    n_k: usize,
    d: usize,
) -> Result<()> {
    if x.len() != n_k * d || y.len() != n_k || norms.len() != n_k {
        return Err(anyhow!(
            "register shapes inconsistent: x={} y={} norms={} for {n_k}x{d}",
            x.len(),
            y.len(),
            norms.len()
        ));
    }
    let x = xla::Literal::vec1(&x).reshape(&[n_k as i64, d as i64])?;
    let y = xla::Literal::vec1(&y);
    let norms = xla::Literal::vec1(&norms);
    st.blocks.insert(block_id, BlockData { x, y, norms, n_k, d });
    Ok(())
}

/// Ensure the artifact for (kernel, loss, shape) is compiled; returns its
/// cache key and idx capacity. (Split from the lookup so callers can hold
/// immutable borrows of both the executable and the block data.)
fn ensure_compiled(
    st: &mut EngineState,
    kernel: &str,
    loss: &str,
    n_k: usize,
    d: usize,
) -> Result<(String, usize)> {
    let entry = st
        .manifest
        .find(kernel, loss, n_k, d)
        .ok_or_else(|| {
            anyhow!(
                "no AOT artifact for kernel={kernel} loss={loss} shape={n_k}x{d}; \
                 add the spec to python/compile/aot.py and re-run `make artifacts`"
            )
        })?
        .clone();
    if !st.executables.contains_key(&entry.name) {
        let path = st.manifest.path_of(&st.dir, &entry);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = st.client.compile(&comp)?;
        st.executables.insert(entry.name.clone(), exe);
    }
    Ok((entry.name, entry.cap))
}

fn run_sdca(
    st: &mut EngineState,
    block_id: usize,
    loss: &str,
    mut alpha: Vec<f32>,
    mut w: Vec<f32>,
    idx: Vec<i32>,
    lam_n: f32,
    gamma: f32,
) -> Result<SdcaOut> {
    let t0 = std::time::Instant::now();
    let (n_k, d) = {
        let b = st.blocks.get(&block_id).ok_or_else(|| anyhow!("unknown block {block_id}"))?;
        (b.n_k, b.d)
    };
    if alpha.len() != n_k || w.len() != d {
        return Err(anyhow!("sdca input shapes inconsistent"));
    }
    let (exe_name, cap) = ensure_compiled(st, "local_sdca", loss, n_k, d)?;
    if cap == 0 {
        return Err(anyhow!("artifact has zero idx capacity"));
    }
    let mut dalpha_total = vec![0.0f32; n_k];
    let mut dw_total = vec![0.0f32; d];
    // Chunk H over the artifact's idx capacity, feeding each chunk the
    // locally-updated (alpha, w) — semantically identical to one long run.
    for chunk in idx.chunks(cap) {
        let h = chunk.len();
        let mut idx_buf = vec![0i32; cap];
        idx_buf[..h].copy_from_slice(chunk);
        let scalars = [lam_n, gamma, h as f32];
        let exe = st.executables.get(&exe_name).unwrap();
        let block = st.blocks.get(&block_id).unwrap();
        let args = [
            block.x.clone(),
            block.y.clone(),
            xla::Literal::vec1(&alpha),
            xla::Literal::vec1(&w),
            xla::Literal::vec1(&idx_buf),
            block.norms.clone(),
            xla::Literal::vec1(&scalars),
        ];
        let result = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let (da_lit, dw_lit) = result.to_tuple2()?;
        let da = da_lit.to_vec::<f32>()?;
        let dw = dw_lit.to_vec::<f32>()?;
        for i in 0..n_k {
            dalpha_total[i] += da[i];
            alpha[i] += da[i];
        }
        for j in 0..d {
            dw_total[j] += dw[j];
            w[j] += dw[j];
        }
    }
    Ok(SdcaOut {
        dalpha: dalpha_total,
        dw: dw_total,
        compute_s: t0.elapsed().as_secs_f64(),
    })
}

fn run_eval(
    st: &mut EngineState,
    block_id: usize,
    loss: &str,
    alpha: Vec<f32>,
    w: Vec<f32>,
    gamma: f32,
) -> Result<EvalOut> {
    let t0 = std::time::Instant::now();
    let (n_k, d) = {
        let b = st.blocks.get(&block_id).ok_or_else(|| anyhow!("unknown block {block_id}"))?;
        (b.n_k, b.d)
    };
    let (exe_name, _) = ensure_compiled(st, "eval_objectives", loss, n_k, d)?;
    let exe = st.executables.get(&exe_name).unwrap();
    let block = st.blocks.get(&block_id).unwrap();
    let gamma_lit = xla::Literal::vec1(&[gamma]).reshape(&[])?;
    let args = [
        block.x.clone(),
        block.y.clone(),
        xla::Literal::vec1(&alpha),
        xla::Literal::vec1(&w),
        gamma_lit,
    ];
    let result = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
    let (ls, cs) = result.to_tuple2()?;
    Ok(EvalOut {
        loss_sum: ls.to_vec::<f32>()?[0] as f64,
        conj_sum: cs.to_vec::<f32>()?[0] as f64,
        compute_s: t0.elapsed().as_secs_f64(),
    })
}
