//! `artifacts/manifest.tsv` — the contract between `python/compile/aot.py`
//! and the rust runtime. One row per AOT-lowered (kernel, loss, shape)
//! variant. (aot.py also writes a manifest.json for humans; the runtime
//! consumes the TSV because this build vendors no JSON parser.)

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    /// "local_sdca" | "eval_objectives"
    pub kernel: String,
    /// "hinge" | "smoothed_hinge" | "squared" | "logistic"
    pub loss: String,
    pub n_k: usize,
    pub d: usize,
    /// idx capacity (max H per execute); 0 for kernels without idx.
    pub cap: usize,
    pub sha256: String,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub version: u32,
    pub artifacts: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("read {} (run `make artifacts` first)", path.display())
        })?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header = lines.next().context("empty manifest")?;
        let (tag, version) = header
            .split_once('\t')
            .context("manifest header must be `#cocoa-manifest\\t<version>`")?;
        if tag != "#cocoa-manifest" {
            bail!("bad manifest header tag {tag:?}");
        }
        let version: u32 = version.trim().parse().context("manifest version")?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let mut artifacts = Vec::new();
        for (i, line) in lines.enumerate() {
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 8 {
                bail!("manifest row {} has {} columns, want 8", i + 2, cols.len());
            }
            artifacts.push(ArtifactEntry {
                name: cols[0].to_string(),
                file: cols[1].to_string(),
                kernel: cols[2].to_string(),
                loss: cols[3].to_string(),
                n_k: cols[4].parse().with_context(|| format!("row {}: n_k", i + 2))?,
                d: cols[5].parse().with_context(|| format!("row {}: d", i + 2))?,
                cap: cols[6].parse().with_context(|| format!("row {}: cap", i + 2))?,
                sha256: cols[7].to_string(),
            });
        }
        if artifacts.is_empty() {
            return Err(anyhow!("manifest lists no artifacts"));
        }
        Ok(Manifest { version, artifacts })
    }

    /// Find the artifact for (kernel, loss) with exactly the block shape.
    pub fn find(&self, kernel: &str, loss: &str, n_k: usize, d: usize) -> Option<&ArtifactEntry> {
        self.artifacts
            .iter()
            .find(|a| a.kernel == kernel && a.loss == loss && a.n_k == n_k && a.d == d)
    }

    pub fn path_of(&self, dir: &Path, entry: &ArtifactEntry) -> PathBuf {
        dir.join(&entry.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "#cocoa-manifest\t1\n\
        local_sdca_hinge_8x4_c16\ta.hlo.txt\tlocal_sdca\thinge\t8\t4\t16\tdeadbeef\n\
        eval_objectives_hinge_8x4\tb.hlo.txt\teval_objectives\thinge\t8\t4\t0\tfeedface\n";

    #[test]
    fn parse_and_find() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        assert!(m.find("local_sdca", "hinge", 8, 4).is_some());
        assert!(m.find("local_sdca", "hinge", 8, 5).is_none());
        assert!(m.find("local_sdca", "squared", 8, 4).is_none());
        assert_eq!(m.find("eval_objectives", "hinge", 8, 4).unwrap().cap, 0);
    }

    #[test]
    fn rejects_bad_versions_and_shapes() {
        assert!(Manifest::parse("#cocoa-manifest\t9\nx\ty\tz\tw\t1\t1\t1\ts").is_err());
        assert!(Manifest::parse("#wrong\t1\n").is_err());
        assert!(Manifest::parse("#cocoa-manifest\t1\nshort\trow\n").is_err());
        assert!(Manifest::parse("#cocoa-manifest\t1\n").is_err()); // empty
    }

    #[test]
    fn load_real_manifest_if_built() {
        // soft check against the actual artifacts dir when present
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.tsv").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.artifacts.is_empty());
            for a in &m.artifacts {
                assert!(m.path_of(&dir, a).exists(), "missing {}", a.file);
            }
        }
    }
}
