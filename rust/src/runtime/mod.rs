//! PJRT runtime — loads and executes the AOT-compiled JAX/Pallas artifacts.
//!
//! `make artifacts` runs python once to lower the L2/L1 graphs to HLO text;
//! this module is everything that touches them afterwards:
//!
//! * [`manifest`] — the artifact index written by `aot.py`.
//! * [`engine`] — the dedicated thread owning the `xla::PjRtClient`, the
//!   compiled executables, and per-block data literals.
//! * [`PjrtLocalSdca`] — a [`crate::solvers::LocalDualMethod`] backed by
//!   the Pallas `local_sdca` kernel, so the coordinator can swap the native
//!   rust inner loop for the XLA-compiled one per worker.
//!
//! Shapes are static in the artifacts: the block must match an entry in the
//! manifest exactly (pad the dataset or add a spec to `aot.py` otherwise).

mod engine;
mod manifest;

pub use engine::{Engine, EngineHandle, EvalOut, SdcaOut};
pub use manifest::{ArtifactEntry, Manifest};

use crate::loss::Loss;
use crate::util::Rng;
use crate::solvers::{Block, LocalDualMethod, LocalUpdate};

/// LocalSDCA via the AOT Pallas kernel. Each instance is bound to a block
/// previously registered with the engine under `block_id`.
pub struct PjrtLocalSdca {
    pub handle: EngineHandle,
    pub block_id: usize,
    pub loss_name: &'static str,
    pub gamma: f64,
}

impl PjrtLocalSdca {
    /// Register the block's static data with the engine and return the
    /// solver. Sparse features are densified (the kernel is dense).
    pub fn bind(
        handle: EngineHandle,
        block_id: usize,
        block: &Block,
        loss_name: &'static str,
        gamma: f64,
    ) -> anyhow::Result<Self> {
        let n_k = block.n_k();
        let d = block.d();
        let mut x = Vec::with_capacity(n_k * d);
        for i in 0..n_k {
            for v in block.data.features.row_dense(i) {
                x.push(v as f32);
            }
        }
        let y: Vec<f32> = block.data.labels.iter().map(|&v| v as f32).collect();
        let norms: Vec<f32> = (0..n_k).map(|i| block.data.norm_sq(i) as f32).collect();
        handle.register_block(block_id, x, y, norms, n_k, d)?;
        Ok(PjrtLocalSdca { handle, block_id, loss_name, gamma })
    }
}

impl LocalDualMethod for PjrtLocalSdca {
    fn name(&self) -> &'static str {
        "pjrt_local_sdca"
    }

    fn local_update(
        &self,
        block: &Block,
        _loss: &dyn Loss,
        alpha: &[f64],
        w: &[f64],
        h: usize,
        rng: &mut Rng,
    ) -> LocalUpdate {
        // Host-side randomness: the same ChaCha stream a native LocalSdca
        // would consume, so the two backends are comparable run-for-run.
        let n_k = block.n_k();
        let idx: Vec<i32> = (0..h).map(|_| rng.gen_range(n_k) as i32).collect();
        let alpha_f32: Vec<f32> = alpha.iter().map(|&v| v as f32).collect();
        let w_f32: Vec<f32> = w.iter().map(|&v| v as f32).collect();
        let out = self
            .handle
            .local_sdca(
                self.block_id,
                self.loss_name,
                alpha_f32,
                w_f32,
                idx,
                block.lambda_n as f32,
                self.gamma as f32,
            )
            .expect("PJRT local_sdca failed");
        LocalUpdate {
            dalpha: out.dalpha.iter().map(|&v| v as f64).collect(),
            dw: out.dw.iter().map(|&v| v as f64).collect(),
            steps: h as u64,
            offloaded_s: out.compute_s,
        }
    }
}
