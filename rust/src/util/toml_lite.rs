//! Minimal TOML-subset parser — the offline substrate behind the config
//! system (the build has no network access to the serde/toml crates).
//!
//! Supported grammar (everything the experiment configs use):
//!   * `[section]` / `[section.sub]` headers
//!   * `key = value` with string, integer, float, boolean values
//!   * `#` comments and blank lines
//!
//! Unsupported on purpose (config files simply avoid them): arrays, inline
//! tables, multi-line strings, dotted keys, datetimes.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().and_then(|i| u64::try_from(i).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed document: section name ("" for top level) -> key -> value.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Doc {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc> {
        let mut doc = Doc::default();
        let mut current = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: unterminated section", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                current = name.to_string();
                doc.sections.entry(current.clone()).or_default();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let value = parse_value(value.trim())
                .with_context(|| format!("line {}: bad value {:?}", lineno + 1, value.trim()))?;
            doc.sections
                .entry(current.clone())
                .or_default()
                .insert(key.to_string(), value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn has_section(&self, section: &str) -> bool {
        self.sections.contains_key(section)
    }

    pub fn section_keys(&self, section: &str) -> Vec<&str> {
        self.sections
            .get(section)
            .map(|m| m.keys().map(String::as_str).collect())
            .unwrap_or_default()
    }

    // typed accessors with good error messages -------------------------

    pub fn str_of(&self, section: &str, key: &str) -> Result<&str> {
        self.get(section, key)
            .and_then(Value::as_str)
            .with_context(|| format!("missing string [{section}] {key}"))
    }

    pub fn f64_of(&self, section: &str, key: &str) -> Result<f64> {
        self.get(section, key)
            .and_then(Value::as_f64)
            .with_context(|| format!("missing number [{section}] {key}"))
    }

    pub fn usize_of(&self, section: &str, key: &str) -> Result<usize> {
        self.get(section, key)
            .and_then(Value::as_usize)
            .with_context(|| format!("missing integer [{section}] {key}"))
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        self.get(section, key).and_then(Value::as_usize).unwrap_or(default)
    }

    pub fn u64_or(&self, section: &str, key: &str, default: u64) -> u64 {
        self.get(section, key).and_then(Value::as_u64).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).and_then(Value::as_str).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // a '#' inside a quoted string does not start a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .context("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    // integer first, then float (TOML floats: ., e/E, inf, nan)
    if !s.contains('.') && !s.contains(['e', 'E']) {
        if let Ok(i) = s.replace('_', "").parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("unrecognized value")
}

/// Write helper: formats a value back to the subset syntax.
pub fn format_value(v: &Value) -> String {
    match v {
        Value::Str(s) => format!("\"{s}\""),
        Value::Int(i) => i.to_string(),
        Value::Float(f) => {
            if f.fract() == 0.0 && f.abs() < 1e15 {
                format!("{f:.1}")
            } else {
                format!("{f}")
            }
        }
        Value::Bool(b) => b.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# top-level
lambda = 1e-4
name = "cov experiment"
verbose = true
count = 1_000

[dataset]
kind = "cov_like"  # inline comment
n = 1000
noise = 0.1

[run.inner]
rounds = 50
"#;

    #[test]
    fn parses_sections_and_types() {
        let doc = Doc::parse(SAMPLE).unwrap();
        assert_eq!(doc.f64_of("", "lambda").unwrap(), 1e-4);
        assert_eq!(doc.str_of("", "name").unwrap(), "cov experiment");
        assert_eq!(doc.get("", "verbose").unwrap().as_bool(), Some(true));
        assert_eq!(doc.usize_of("", "count").unwrap(), 1000);
        assert_eq!(doc.str_of("dataset", "kind").unwrap(), "cov_like");
        assert_eq!(doc.usize_of("dataset", "n").unwrap(), 1000);
        assert_eq!(doc.f64_of("dataset", "noise").unwrap(), 0.1);
        assert_eq!(doc.usize_of("run.inner", "rounds").unwrap(), 50);
    }

    #[test]
    fn int_vs_float_distinction() {
        let doc = Doc::parse("a = 3\nb = 3.0\nc = 3e0").unwrap();
        assert!(matches!(doc.get("", "a"), Some(Value::Int(3))));
        assert!(matches!(doc.get("", "b"), Some(Value::Float(_))));
        assert!(matches!(doc.get("", "c"), Some(Value::Float(_))));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = Doc::parse("path = \"a#b\"").unwrap();
        assert_eq!(doc.str_of("", "path").unwrap(), "a#b");
    }

    #[test]
    fn errors_are_line_numbered() {
        let err = Doc::parse("ok = 1\nbroken").unwrap_err();
        assert!(format!("{err:#}").contains("line 2"));
        let err = Doc::parse("[unterminated").unwrap_err();
        assert!(format!("{err:#}").contains("line 1"));
    }

    #[test]
    fn defaults_helpers() {
        let doc = Doc::parse("[s]\nx = 5").unwrap();
        assert_eq!(doc.usize_or("s", "x", 9), 5);
        assert_eq!(doc.usize_or("s", "missing", 9), 9);
        assert_eq!(doc.str_or("s", "missing", "dflt"), "dflt");
    }

    #[test]
    fn format_value_roundtrips() {
        for v in [
            Value::Str("hi".into()),
            Value::Int(-3),
            Value::Float(2.5),
            Value::Bool(false),
        ] {
            let text = format!("k = {}", format_value(&v));
            let doc = Doc::parse(&text).unwrap();
            assert_eq!(doc.get("", "k"), Some(&v));
        }
    }
}
