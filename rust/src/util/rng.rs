//! Deterministic, seedable PRNG substrate (xoshiro256** + splitmix64).
//!
//! The build is offline (no `rand` crate), and the framework needs
//! reproducible randomness in four places: synthetic data generation,
//! coordinate sampling in LocalSDCA, partition shuffles, and the power
//! iteration's start vector. xoshiro256** is small, fast, and
//! statistically solid for all of them; every consumer takes an explicit
//! seed so runs are replayable.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller sample
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Rejection-free multiply-shift (Lemire);
    /// the tiny bias (< 2^-64) is irrelevant here.
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform float in [lo, hi).
    #[inline]
    pub fn gen_range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.gen_f64()
    }

    /// true with probability p.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box-Muller (pairs cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let u1 = self.gen_f64().max(f64::MIN_POSITIVE);
        let u2 = self.gen_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare_normal = Some(r * s);
        r * c
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Export the generator state (checkpointing). The Box-Muller spare is
    /// intentionally dropped: resuming re-draws it, which only affects the
    /// parity of normal() calls, never uniform streams.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from an exported state.
    pub fn from_state(s: [u64; 4]) -> Self {
        Rng { s, spare_normal: None }
    }

    /// `k` distinct indices from [0, n) (Floyd's algorithm, order random).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.gen_range(j + 1);
            if chosen.insert(t) {
                out.push(t);
            } else {
                chosen.insert(j);
                out.push(j);
            }
        }
        self.shuffle(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..10_000 {
            let v = r.gen_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gen_range_uniform_ish() {
        let mut r = Rng::seed_from_u64(2);
        let n = 10;
        let mut counts = vec![0usize; n];
        let trials = 100_000;
        for _ in 0..trials {
            counts[r.gen_range(n)] += 1;
        }
        let expect = trials / n;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as i64 - expect as i64).abs() < (expect as i64) / 5,
                "bucket {i}: {c} vs {expect}"
            );
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(3);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sum_sq = 0.0;
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sum_sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sum_sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(4);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Rng::seed_from_u64(5);
        for _ in 0..100 {
            let s = r.sample_distinct(20, 7);
            assert_eq!(s.len(), 7);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 7);
            assert!(s.iter().all(|&x| x < 20));
        }
        // k == n covers everything
        let mut all = r.sample_distinct(9, 9);
        all.sort_unstable();
        assert_eq!(all, (0..9).collect::<Vec<_>>());
    }
}
