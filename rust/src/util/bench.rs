//! Minimal benchmarking harness (offline build: no criterion).
//!
//! Measures wall time over adaptive iteration counts, reports
//! median/p10/p90 like criterion's summary line. Used by the
//! `rust/benches/*.rs` targets (`cargo bench`).

use std::time::Instant;

/// One benchmark measurement.
pub struct Measurement {
    pub name: String,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub iters_per_sample: u64,
}

impl Measurement {
    pub fn print(&self) {
        println!(
            "{:<44} median {:>12}  p10 {:>12}  p90 {:>12}  ({} it/sample)",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.p10_ns),
            fmt_ns(self.p90_ns),
            self.iters_per_sample
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark `f`, auto-calibrating the per-sample iteration count so one
/// sample takes ~`target_sample_ms`, then collecting `samples` samples.
pub fn bench(name: &str, samples: usize, target_sample_ms: f64, mut f: impl FnMut()) -> Measurement {
    // calibrate
    let mut iters: u64 = 1;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let elapsed = t0.elapsed().as_secs_f64() * 1e3;
        if elapsed >= target_sample_ms || iters >= 1 << 30 {
            break;
        }
        let scale = (target_sample_ms / elapsed.max(1e-6)).clamp(1.5, 100.0);
        iters = ((iters as f64) * scale).ceil() as u64;
    }
    // measure
    let mut per_iter: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        per_iter.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    per_iter.sort_by(f64::total_cmp);
    let pct = |p: f64| per_iter[((per_iter.len() - 1) as f64 * p).round() as usize];
    let m = Measurement {
        name: name.to_string(),
        median_ns: pct(0.5),
        p10_ns: pct(0.1),
        p90_ns: pct(0.9),
        iters_per_sample: iters,
    };
    m.print();
    m
}

/// Time a single long-running closure (end-to-end benches).
pub fn time_once<T>(name: &str, f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    let secs = t0.elapsed().as_secs_f64();
    println!("{name:<44} {:.3} s", secs);
    (out, secs)
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something_sane() {
        let mut acc = 0u64;
        let m = bench("noop-ish", 5, 0.2, || {
            acc = acc.wrapping_add(black_box(1));
        });
        assert!(m.median_ns > 0.0);
        assert!(m.p10_ns <= m.median_ns && m.median_ns <= m.p90_ns);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(5.0).contains("ns"));
        assert!(fmt_ns(5e3).contains("µs"));
        assert!(fmt_ns(5e6).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }
}
