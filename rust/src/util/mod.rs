//! Offline substrates: the utilities the framework would normally pull
//! from crates.io (rand, toml) built in-tree because this environment
//! vendors only the xla PJRT closure.

pub mod bench;
pub mod rng;
pub mod toml_lite;

pub use rng::Rng;
