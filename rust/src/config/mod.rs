//! TOML experiment configuration — the single declarative entry point the
//! CLI launcher consumes (`cocoa train --config exp.toml`).
//!
//! Parsed with the in-tree [`crate::util::toml_lite`] subset parser
//! (offline build: no serde/toml crates). A parsed [`ExperimentConfig`]
//! converts to the typed API with [`ExperimentConfig::trainer`],
//! [`AlgorithmSpec::instantiate`], and [`RunSpec::budget`].

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::algorithms::{self, Aggregation, Algorithm, Budget};
use crate::api::Trainer;
use crate::data::{self, Dataset, Partition, PartitionStrategy, ShardMode, ShardSet};
use crate::error::Error;
use crate::loss::LossKind;
use crate::netsim::NetworkModel;
use crate::regularizers::RegularizerKind;
use crate::solvers::SolverKind;
use crate::transport::{NetConfig, SimNetConfig, TransportKind};
use crate::util::toml_lite::Doc;

/// Which execution backend workers use for the local dual method.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Backend {
    /// Pure-rust inner loop (any shape, dense or sparse).
    #[default]
    Native,
    /// AOT JAX/Pallas kernel via PJRT (block shape must match an artifact).
    Pjrt,
}

impl Backend {
    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "native" => Some(Backend::Native),
            "pjrt" => Some(Backend::Pjrt),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Pjrt => "pjrt",
        }
    }
}

/// Dataset selection.
#[derive(Debug, Clone, PartialEq)]
pub enum DatasetSpec {
    CovLike { n: usize, d: usize, noise: f64, seed: u64 },
    Rcv1Like { n: usize, d: usize, nnz_per_row: usize, noise: f64, seed: u64 },
    ImagenetLike { n: usize, d: usize, noise: f64, seed: u64 },
    Orthogonal { k: usize, rows_per_block: usize, cols_per_block: usize, seed: u64 },
    Libsvm { path: String, d_hint: usize },
    /// An on-disk shard set written by `cocoa shard` — the out-of-core
    /// path. Declared as `[data] shards = "dir"` (with optional
    /// `mmap = false` to force owned reads); mutually exclusive with
    /// `[dataset]`. Opened via [`ExperimentConfig::open_shards`], never
    /// [`DatasetSpec::load`] — the whole point is not materializing it.
    Shards { dir: String, mmap: bool },
}

impl DatasetSpec {
    pub fn name(&self) -> String {
        match self {
            DatasetSpec::CovLike { n, d, .. } => format!("cov_like_{n}x{d}"),
            DatasetSpec::Rcv1Like { n, d, .. } => format!("rcv1_like_{n}x{d}"),
            DatasetSpec::ImagenetLike { n, d, .. } => format!("imagenet_like_{n}x{d}"),
            DatasetSpec::Orthogonal { k, rows_per_block, .. } => {
                format!("orthogonal_{k}x{rows_per_block}")
            }
            DatasetSpec::Libsvm { path, .. } => Path::new(path)
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| "libsvm".into()),
            DatasetSpec::Shards { dir, .. } => Path::new(dir)
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| "shards".into()),
        }
    }

    /// The shard directory + mmap flag when this spec names an on-disk
    /// shard set (`None` for every in-memory kind).
    pub fn shards(&self) -> Option<(&str, bool)> {
        match self {
            DatasetSpec::Shards { dir, mmap } => Some((dir, *mmap)),
            _ => None,
        }
    }

    pub fn load(&self) -> Result<Dataset> {
        Ok(match self {
            DatasetSpec::CovLike { n, d, noise, seed } => data::cov_like(*n, *d, *noise, *seed),
            DatasetSpec::Rcv1Like { n, d, nnz_per_row, noise, seed } => {
                data::rcv1_like(*n, *d, *nnz_per_row, *noise, *seed)
            }
            DatasetSpec::ImagenetLike { n, d, noise, seed } => {
                data::imagenet_like(*n, *d, *noise, *seed)
            }
            DatasetSpec::Orthogonal { k, rows_per_block, cols_per_block, seed } => {
                data::orthogonal_blocks(*k, *rows_per_block, *cols_per_block, *seed)
            }
            DatasetSpec::Libsvm { path, d_hint } => {
                let mut ds = data::read_libsvm(path, *d_hint)?;
                ds.normalize_rows();
                ds
            }
            DatasetSpec::Shards { dir, .. } => bail!(
                "shard set {dir:?} is not loadable as an in-memory dataset: \
                 open it with ExperimentConfig::open_shards (the out-of-core path)"
            ),
        })
    }

    fn from_doc(doc: &Doc) -> Result<Self> {
        // the out-of-core surface: `[data] shards = "dir"` names an
        // on-disk shard set instead of an in-memory [dataset]
        if let Some(dir) = doc.get("data", "shards").and_then(|v| v.as_str()) {
            if doc.has_section("dataset") {
                bail!("[data] shards = ... and [dataset] are mutually exclusive");
            }
            return Ok(DatasetSpec::Shards {
                dir: dir.to_string(),
                mmap: doc.get("data", "mmap").and_then(|v| v.as_bool()).unwrap_or(true),
            });
        }
        let kind = doc.str_of("dataset", "kind")?;
        let noise = doc.f64_or("dataset", "noise", 0.1);
        let seed = doc.u64_or("dataset", "seed", 0);
        Ok(match kind {
            "cov_like" => DatasetSpec::CovLike {
                n: doc.usize_of("dataset", "n")?,
                d: doc.usize_of("dataset", "d")?,
                noise,
                seed,
            },
            "rcv1_like" => DatasetSpec::Rcv1Like {
                n: doc.usize_of("dataset", "n")?,
                d: doc.usize_of("dataset", "d")?,
                nnz_per_row: doc.usize_or("dataset", "nnz_per_row", 12),
                noise,
                seed,
            },
            "imagenet_like" => DatasetSpec::ImagenetLike {
                n: doc.usize_of("dataset", "n")?,
                d: doc.usize_of("dataset", "d")?,
                noise,
                seed,
            },
            "orthogonal" => DatasetSpec::Orthogonal {
                k: doc.usize_of("dataset", "k")?,
                rows_per_block: doc.usize_of("dataset", "rows_per_block")?,
                cols_per_block: doc.usize_of("dataset", "cols_per_block")?,
                seed,
            },
            "libsvm" => DatasetSpec::Libsvm {
                path: doc.str_of("dataset", "path")?.to_string(),
                d_hint: doc.usize_or("dataset", "d_hint", 0),
            },
            other => bail!("unknown dataset kind {other:?}"),
        })
    }
}

/// Algorithm selection + hyperparameters (Section 6's competitors).
#[derive(Debug, Clone, PartialEq)]
pub enum AlgorithmSpec {
    /// Algorithm 1 with the configured local solver.
    Cocoa { h: usize, beta_k: f64, solver: SolverKind },
    /// Extension (the conclusion's beta_K > 1 open problem, resolved by the
    /// CoCoA+ follow-up): ADD the K updates (beta_K = K) while scaling the
    /// local subproblem curvature by sigma' = K so the aggressive
    /// aggregation stays safe.
    CocoaPlus { h: usize },
    /// Mini-batch SDCA (mini-batch-CD in the figures).
    MinibatchCd { h: usize, beta_b: f64 },
    /// Mini-batch Pegasos.
    MinibatchSgd { h: usize, beta: f64 },
    /// Locally-updating Pegasos.
    LocalSgd { h: usize, beta: f64 },
    /// Communicate after every coordinate update (H = 1 CoCoA).
    NaiveCd,
    /// Communicate after every SGD step.
    NaiveSgd,
    /// One round: solve each block to optimality and average [ZDW13].
    OneShotAvg,
}

impl AlgorithmSpec {
    pub fn name(&self) -> &'static str {
        match self {
            AlgorithmSpec::Cocoa { .. } => "cocoa",
            AlgorithmSpec::CocoaPlus { .. } => "cocoa_plus",
            AlgorithmSpec::MinibatchCd { .. } => "minibatch_cd",
            AlgorithmSpec::MinibatchSgd { .. } => "minibatch_sgd",
            AlgorithmSpec::LocalSgd { .. } => "local_sgd",
            AlgorithmSpec::NaiveCd => "naive_cd",
            AlgorithmSpec::NaiveSgd => "naive_sgd",
            AlgorithmSpec::OneShotAvg => "one_shot_avg",
        }
    }

    pub fn h(&self) -> usize {
        match self {
            AlgorithmSpec::Cocoa { h, .. }
            | AlgorithmSpec::CocoaPlus { h }
            | AlgorithmSpec::MinibatchCd { h, .. }
            | AlgorithmSpec::MinibatchSgd { h, .. }
            | AlgorithmSpec::LocalSgd { h, .. } => *h,
            AlgorithmSpec::NaiveCd | AlgorithmSpec::NaiveSgd => 1,
            AlgorithmSpec::OneShotAvg => 0,
        }
    }

    pub fn beta(&self) -> f64 {
        match self {
            AlgorithmSpec::Cocoa { beta_k, .. } => *beta_k,
            AlgorithmSpec::MinibatchCd { beta_b, .. } => *beta_b,
            AlgorithmSpec::MinibatchSgd { beta, .. } | AlgorithmSpec::LocalSgd { beta, .. } => {
                *beta
            }
            _ => 1.0,
        }
    }

    /// The local solver this spec asks for (only CoCoA carries one; every
    /// other method's local work is fixed by its definition).
    pub fn solver_kind(&self) -> SolverKind {
        match self {
            AlgorithmSpec::Cocoa { solver, .. } => *solver,
            _ => SolverKind::Sdca,
        }
    }

    /// Construct the runnable [`Algorithm`] this declarative spec names.
    /// Equivalence (same `name()`, `h()`, `beta()`) is guarded by a
    /// property test over every spec the parser accepts.
    pub fn instantiate(&self) -> Box<dyn Algorithm> {
        match self {
            AlgorithmSpec::Cocoa { h, beta_k, .. } => Box::new(
                algorithms::Cocoa::new(*h).aggregation(Aggregation::Average { beta_k: *beta_k }),
            ),
            AlgorithmSpec::CocoaPlus { h } => Box::new(algorithms::Cocoa::adding(*h)),
            AlgorithmSpec::MinibatchCd { h, beta_b } => {
                Box::new(algorithms::MinibatchCd::new(*h).beta_b(*beta_b))
            }
            AlgorithmSpec::MinibatchSgd { h, beta } => {
                Box::new(algorithms::MinibatchSgd::new(*h).beta(*beta))
            }
            AlgorithmSpec::LocalSgd { h, beta } => {
                Box::new(algorithms::LocalSgd::new(*h).beta(*beta))
            }
            AlgorithmSpec::NaiveCd => Box::new(algorithms::NaiveCd),
            AlgorithmSpec::NaiveSgd => Box::new(algorithms::NaiveSgd::new()),
            AlgorithmSpec::OneShotAvg => Box::new(algorithms::OneShotAvg),
        }
    }

    fn from_doc(doc: &Doc) -> Result<Self> {
        let name = doc.str_of("algorithm", "name")?;
        let h = || doc.usize_of("algorithm", "h");
        Ok(match name {
            "cocoa" => AlgorithmSpec::Cocoa {
                h: h()?,
                beta_k: doc.f64_or("algorithm", "beta_k", 1.0),
                solver: match doc.str_or("algorithm", "solver", "sdca") {
                    "sdca" => SolverKind::Sdca,
                    "sdca_perm" => SolverKind::SdcaPerm,
                    "exact" => SolverKind::Exact,
                    "gap_certified" => SolverKind::GapCertified,
                    other => bail!("unknown solver {other:?}"),
                },
            },
            "cocoa_plus" => AlgorithmSpec::CocoaPlus { h: h()? },
            "minibatch_cd" => AlgorithmSpec::MinibatchCd {
                h: h()?,
                beta_b: doc.f64_or("algorithm", "beta_b", 1.0),
            },
            "minibatch_sgd" => AlgorithmSpec::MinibatchSgd {
                h: h()?,
                beta: doc.f64_or("algorithm", "beta", 1.0),
            },
            "local_sgd" => AlgorithmSpec::LocalSgd {
                h: h()?,
                beta: doc.f64_or("algorithm", "beta", 1.0),
            },
            "naive_cd" => AlgorithmSpec::NaiveCd,
            "naive_sgd" => AlgorithmSpec::NaiveSgd,
            "one_shot_avg" => AlgorithmSpec::OneShotAvg,
            other => bail!("unknown algorithm {other:?}"),
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct PartitionSpec {
    pub k: usize,
    pub strategy: PartitionStrategy,
    pub seed: u64,
}

impl PartitionSpec {
    pub fn build(&self, n: usize) -> Partition {
        Partition::new(self.strategy, n, self.k, self.seed)
    }

    fn from_doc(doc: &Doc) -> Result<Self> {
        let strategy_name = doc.str_or("partition", "strategy", "contiguous");
        Ok(PartitionSpec {
            k: doc.usize_of("partition", "k")?,
            strategy: PartitionStrategy::from_name(strategy_name)
                .ok_or_else(|| anyhow!("unknown partition strategy {strategy_name:?}"))?,
            seed: doc.u64_or("partition", "seed", 0),
        })
    }
}

/// Run budget / stopping criteria.
#[derive(Debug, Clone, PartialEq)]
pub struct RunSpec {
    /// Max outer rounds (T in Algorithm 1).
    pub rounds: u64,
    /// Stop when the duality gap falls below this (0 disables).
    pub target_gap: f64,
    /// Stop when P(w) - P* falls below this (requires a known optimum).
    pub target_subopt: f64,
    /// Evaluate P/D/gap every this many rounds.
    pub eval_every: u64,
    pub seed: u64,
    pub backend: Backend,
}

impl RunSpec {
    /// The typed [`Budget`] this run section describes.
    pub fn budget(&self) -> Budget {
        Budget::rounds(self.rounds)
            .target_gap(self.target_gap)
            .target_subopt(self.target_subopt)
            .eval_every(self.eval_every)
    }

    fn from_doc(doc: &Doc) -> Result<Self> {
        let backend_name = doc.str_or("run", "backend", "native");
        Ok(RunSpec {
            rounds: doc.u64_or("run", "rounds", 50),
            target_gap: doc.f64_or("run", "target_gap", 0.0),
            target_subopt: doc.f64_or("run", "target_subopt", 0.0),
            eval_every: doc.u64_or("run", "eval_every", 1),
            seed: doc.u64_or("run", "seed", 0),
            backend: Backend::from_name(backend_name)
                .ok_or_else(|| anyhow!("unknown backend {backend_name:?}"))?,
        })
    }
}

/// The `[runtime]` section: how workers execute their local solves.
/// Unlike `[netsim]`/`[transport]` these knobs *do* shape the trajectory:
/// with `threads = T > 1` the local solves run the deterministic-per-T
/// sharded schedule (see [`crate::solvers::LocalSdca`]), so T is part of
/// the run identity and folded into the net handshake fingerprint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RuntimeSpec {
    /// Intra-worker shard count T for the local solves (>= 1).
    pub threads: usize,
}

impl Default for RuntimeSpec {
    fn default() -> Self {
        RuntimeSpec { threads: 1 }
    }
}

impl RuntimeSpec {
    fn from_doc(doc: &Doc) -> Result<Self> {
        let threads = doc.usize_or("runtime", "threads", 1);
        if threads == 0 {
            bail!("[runtime] threads must be >= 1 (1 = sequential)");
        }
        Ok(RuntimeSpec { threads })
    }
}

/// The full experiment description.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    pub dataset: DatasetSpec,
    pub partition: PartitionSpec,
    pub algorithm: AlgorithmSpec,
    pub loss: LossKind,
    pub lambda: f64,
    /// The `[regularizer]` section (default plain L2). Parameter ranges
    /// are checked at `Trainer::build`, which returns a typed
    /// `Error::InvalidRegularizer` / `Error::UnsupportedRegularizer`.
    pub regularizer: RegularizerKind,
    pub run: RunSpec,
    /// The `[runtime]` section (default: 1 thread, the sequential path).
    pub runtime: RuntimeSpec,
    pub netsim: NetworkModel,
    /// Leader <-> worker transport backend (`[transport]` section; default
    /// inproc). Range checks happen at `Trainer::build`, which returns a
    /// typed `Error::InvalidTransport`.
    pub transport: TransportKind,
    /// Where HLO artifacts live (Backend::Pjrt).
    pub artifacts_dir: String,
}

impl ExperimentConfig {
    pub fn from_toml_file<P: AsRef<Path>>(path: P) -> Result<Self, Error> {
        let parse = || -> Result<Self> {
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("read {}", path.as_ref().display()))?;
            Self::parse_toml(&text)
                .with_context(|| format!("in config {}", path.as_ref().display()))
        };
        parse().map_err(|e| Error::Config { message: format!("{e:#}") })
    }

    pub fn from_toml(text: &str) -> Result<Self, Error> {
        Self::parse_toml(text).map_err(|e| Error::Config { message: format!("{e:#}") })
    }

    /// A [`Trainer`] pre-filled from this config (the dataset is loaded
    /// separately so the caller controls its lifetime):
    ///
    /// ```no_run
    /// # fn main() -> cocoa::Result<()> {
    /// let cfg = cocoa::ExperimentConfig::from_toml_file("exp.toml")?;
    /// let data = cfg.dataset.load()?;
    /// let mut session = cfg.trainer(&data).build()?;
    /// let trace = session.run(cfg.algorithm.instantiate().as_mut(), cfg.run.budget())?;
    /// # Ok(())
    /// # }
    /// ```
    pub fn trainer<'a>(&self, data: &'a Dataset) -> Trainer<'a> {
        Trainer::on(data)
            .partition(self.partition.build(data.n()))
            .loss(self.loss)
            .lambda(self.lambda)
            .regularizer(self.regularizer)
            .solver(self.algorithm.solver_kind())
            .backend(self.run.backend)
            .artifacts_dir(self.artifacts_dir.as_str())
            .network(self.netsim)
            .transport(self.transport.clone())
            .seed(self.run.seed)
            .threads(self.runtime.threads)
            .label(self.dataset.name())
    }

    /// Open the shard set a `[data] shards = "dir"` config names,
    /// honoring its `mmap` flag. Typed [`Error::Config`] when the config
    /// is not shard-backed.
    pub fn open_shards(&self) -> Result<ShardSet, Error> {
        match &self.dataset {
            DatasetSpec::Shards { dir, mmap } => {
                let mode = if *mmap { ShardMode::default_mode() } else { ShardMode::Owned };
                ShardSet::open_with_mode(Path::new(dir), mode)
            }
            other => Err(Error::Config {
                message: format!(
                    "dataset {} is not shard-backed: add [data] shards = \"dir\" \
                     (or load it with DatasetSpec::load)",
                    other.name()
                ),
            }),
        }
    }

    /// The shard-backed counterpart of [`ExperimentConfig::trainer`]: a
    /// [`Trainer`] over an opened [`ShardSet`]. The partition comes from
    /// the set's manifest; a `[partition] k` that disagrees with the
    /// set's shard count surfaces as a typed error at `build()`.
    pub fn trainer_shards<'a>(&self, set: &'a ShardSet) -> Trainer<'a> {
        let t = Trainer::on_shards(set)
            .loss(self.loss)
            .lambda(self.lambda)
            .regularizer(self.regularizer)
            .solver(self.algorithm.solver_kind())
            .backend(self.run.backend)
            .artifacts_dir(self.artifacts_dir.as_str())
            .network(self.netsim)
            .transport(self.transport.clone())
            .seed(self.run.seed)
            .threads(self.runtime.threads)
            .label(self.dataset.name());
        // k = 0 means the config had no [partition] section (the manifest
        // is authoritative); a stated k is restated so build() checks it
        if self.partition.k == 0 { t } else { t.workers(self.partition.k) }
    }

    fn parse_toml(text: &str) -> Result<Self> {
        let doc = Doc::parse(text)?;
        let loss_name = doc.str_or("loss", "kind", "hinge");
        let gamma = doc.f64_or("loss", "gamma", 1.0);
        let loss = LossKind::from_name(loss_name, gamma)
            .ok_or_else(|| anyhow!("unknown loss {loss_name:?}"))?;
        let regularizer = if doc.has_section("regularizer") {
            match doc.str_or("regularizer", "kind", "l2") {
                "l2" => RegularizerKind::L2,
                "l1" => RegularizerKind::L1 {
                    epsilon: doc.f64_or("regularizer", "epsilon", 0.5),
                },
                "elastic_net" => RegularizerKind::ElasticNet {
                    l1_ratio: doc.f64_or("regularizer", "l1_ratio", 0.5),
                },
                other => bail!("unknown regularizer kind {other:?} (l2|l1|elastic_net)"),
            }
        } else {
            RegularizerKind::L2
        };
        let netsim = if doc.has_section("netsim") {
            if let Some(preset) = doc.get("netsim", "preset").and_then(|v| v.as_str()) {
                NetworkModel::by_name(preset)
                    .ok_or_else(|| anyhow!("unknown netsim preset {preset:?}"))?
            } else {
                NetworkModel {
                    latency_s: doc.f64_or("netsim", "latency_s", 5e-3),
                    bandwidth_bps: doc.f64_or("netsim", "bandwidth_bps", 125e6),
                    bytes_per_scalar: doc.usize_or("netsim", "bytes_per_scalar", 8),
                }
            }
        } else {
            NetworkModel::ec2_like()
        };
        let transport = if doc.has_section("transport") {
            match doc.str_or("transport", "kind", "inproc") {
                "inproc" => TransportKind::InProc,
                "counted" => TransportKind::Counted,
                "record" => TransportKind::Record,
                "simnet" => TransportKind::SimNet(SimNetConfig {
                    seed: doc.u64_or("transport", "seed", 0),
                    jitter_s: doc.f64_or("transport", "jitter_s", 1e-3),
                    drop_prob: doc.f64_or("transport", "drop_prob", 0.0),
                    max_retries: doc.u64_or("transport", "max_retries", 3) as u32,
                    retry_timeout_s: doc.f64_or("transport", "retry_timeout_s", 5e-3),
                    straggler_prob: doc.f64_or("transport", "straggler_prob", 0.0),
                    straggler_slowdown: doc.f64_or("transport", "straggler_slowdown", 1.0),
                }),
                "net" => TransportKind::Net(NetConfig {
                    listen: doc.str_or("transport.net", "listen", "").to_string(),
                    accept_timeout_s: doc.f64_or("transport.net", "accept_timeout_s", 30.0),
                    recv_timeout_s: doc.f64_or("transport.net", "recv_timeout_s", 30.0),
                    record: doc
                        .get("transport.net", "record")
                        .and_then(|v| v.as_bool())
                        .unwrap_or(false),
                }),
                other => bail!(
                    "unknown transport kind {other:?} (inproc|counted|simnet|record|net)"
                ),
            }
        } else {
            TransportKind::InProc
        };
        let dataset = DatasetSpec::from_doc(&doc)?;
        // shard sets carry their partition in the manifest, so [partition]
        // is optional for them; k = 0 records "not stated"
        let partition = if !doc.has_section("partition") && dataset.shards().is_some() {
            PartitionSpec { k: 0, strategy: PartitionStrategy::Contiguous, seed: 0 }
        } else {
            PartitionSpec::from_doc(&doc)?
        };
        Ok(ExperimentConfig {
            dataset,
            partition,
            algorithm: AlgorithmSpec::from_doc(&doc)?,
            loss,
            lambda: doc.f64_of("", "lambda")?,
            regularizer,
            run: RunSpec::from_doc(&doc)?,
            runtime: RuntimeSpec::from_doc(&doc)?,
            netsim,
            transport,
            artifacts_dir: doc.str_or("", "artifacts_dir", "artifacts").to_string(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
lambda = 1e-4

[dataset]
kind = "cov_like"
n = 1000
d = 54
seed = 42

[partition]
k = 4

[algorithm]
name = "cocoa"
h = 250

[loss]
kind = "hinge"

[run]
rounds = 50
target_subopt = 1e-3
"#;

    #[test]
    fn sample_config_parses() {
        let cfg = ExperimentConfig::from_toml(SAMPLE).unwrap();
        assert_eq!(cfg.partition.k, 4);
        assert_eq!(cfg.algorithm.name(), "cocoa");
        assert_eq!(cfg.algorithm.h(), 250);
        assert_eq!(cfg.algorithm.beta(), 1.0);
        assert_eq!(cfg.run.eval_every, 1);
        assert_eq!(cfg.run.backend, Backend::Native);
        assert_eq!(cfg.run.rounds, 50);
        assert_eq!(cfg.run.target_subopt, 1e-3);
        assert_eq!(cfg.loss, LossKind::Hinge);
        assert_eq!(cfg.netsim, NetworkModel::ec2_like());
    }

    #[test]
    fn runtime_section_parses_and_rejects_zero_threads() {
        // no section: the sequential default
        let cfg = ExperimentConfig::from_toml(SAMPLE).unwrap();
        assert_eq!(cfg.runtime, RuntimeSpec::default());
        assert_eq!(cfg.runtime.threads, 1);

        let threaded = format!("{SAMPLE}\n[runtime]\nthreads = 4\n");
        let cfg = ExperimentConfig::from_toml(&threaded).unwrap();
        assert_eq!(cfg.runtime.threads, 4);

        let zero = format!("{SAMPLE}\n[runtime]\nthreads = 0\n");
        let err = ExperimentConfig::from_toml(&zero).unwrap_err();
        assert!(err.to_string().contains("threads"), "{err}");
    }

    #[test]
    fn dataset_spec_loads() {
        let spec = DatasetSpec::CovLike { n: 50, d: 6, noise: 0.1, seed: 1 };
        let ds = spec.load().unwrap();
        assert_eq!(ds.n(), 50);
        assert_eq!(spec.name(), "cov_like_50x6");
    }

    #[test]
    fn explicit_netsim_parses() {
        let text = r#"
lambda = 0.1

[dataset]
kind = "cov_like"
n = 10
d = 2

[partition]
k = 2

[algorithm]
name = "naive_cd"

[loss]
kind = "squared"

[run]
rounds = 5

[netsim]
latency_s = 0.001
bandwidth_bps = 1e9
"#;
        let cfg = ExperimentConfig::from_toml(text).unwrap();
        assert_eq!(cfg.netsim.latency_s, 0.001);
        assert_eq!(cfg.netsim.bandwidth_bps, 1e9);
        assert_eq!(cfg.loss, LossKind::Squared);
    }

    #[test]
    fn netsim_preset_parses() {
        let text = format!("{SAMPLE}\n[netsim]\npreset = \"multicore\"\n");
        let cfg = ExperimentConfig::from_toml(&text).unwrap();
        assert_eq!(cfg.netsim, NetworkModel::multicore());
    }

    #[test]
    fn transport_section_parses() {
        // no section: inproc default
        let cfg = ExperimentConfig::from_toml(SAMPLE).unwrap();
        assert_eq!(cfg.transport, TransportKind::InProc);

        let counted = format!("{SAMPLE}\n[transport]\nkind = \"counted\"\n");
        let cfg = ExperimentConfig::from_toml(&counted).unwrap();
        assert_eq!(cfg.transport, TransportKind::Counted);

        let simnet = format!(
            "{SAMPLE}\n[transport]\nkind = \"simnet\"\nseed = 9\njitter_s = 0.002\n\
             drop_prob = 0.05\nmax_retries = 2\nstraggler_prob = 0.1\n\
             straggler_slowdown = 4.0\n"
        );
        let cfg = ExperimentConfig::from_toml(&simnet).unwrap();
        match &cfg.transport {
            TransportKind::SimNet(c) => {
                assert_eq!(c.seed, 9);
                assert_eq!(c.jitter_s, 0.002);
                assert_eq!(c.drop_prob, 0.05);
                assert_eq!(c.max_retries, 2);
                assert_eq!(c.straggler_prob, 0.1);
                assert_eq!(c.straggler_slowdown, 4.0);
            }
            other => panic!("expected simnet, got {other:?}"),
        }

        let net = format!(
            "{SAMPLE}\n[transport]\nkind = \"net\"\n\
             [transport.net]\nlisten = \"uds:/tmp/cocoa.sock\"\n\
             accept_timeout_s = 5.0\nrecord = true\n"
        );
        let cfg = ExperimentConfig::from_toml(&net).unwrap();
        match &cfg.transport {
            TransportKind::Net(c) => {
                assert_eq!(c.listen, "uds:/tmp/cocoa.sock");
                assert_eq!(c.accept_timeout_s, 5.0);
                assert_eq!(c.recv_timeout_s, 30.0); // default
                assert!(c.record);
            }
            other => panic!("expected net, got {other:?}"),
        }

        let bad = format!("{SAMPLE}\n[transport]\nkind = \"quantum\"\n");
        assert!(ExperimentConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn out_of_range_simnet_config_fails_at_build_with_typed_error() {
        let text = format!(
            "{SAMPLE}\n[transport]\nkind = \"simnet\"\ndrop_prob = 1.0\n"
        );
        let cfg = ExperimentConfig::from_toml(&text).unwrap(); // parse is lenient
        let data = crate::data::cov_like(50, 4, 0.1, 1);
        let err = cfg.trainer(&data).build().unwrap_err();
        assert!(matches!(err, Error::InvalidTransport { .. }), "{err}");
    }

    #[test]
    fn regularizer_section_parses() {
        // no section: plain L2 default
        let cfg = ExperimentConfig::from_toml(SAMPLE).unwrap();
        assert_eq!(cfg.regularizer, RegularizerKind::L2);

        let l1 = format!("{SAMPLE}\n[regularizer]\nkind = \"l1\"\nepsilon = 0.25\n");
        let cfg = ExperimentConfig::from_toml(&l1).unwrap();
        assert_eq!(cfg.regularizer, RegularizerKind::L1 { epsilon: 0.25 });

        let en = format!("{SAMPLE}\n[regularizer]\nkind = \"elastic_net\"\nl1_ratio = 0.7\n");
        let cfg = ExperimentConfig::from_toml(&en).unwrap();
        assert_eq!(cfg.regularizer, RegularizerKind::ElasticNet { l1_ratio: 0.7 });

        let bad = format!("{SAMPLE}\n[regularizer]\nkind = \"l0\"\n");
        assert!(ExperimentConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn out_of_range_regularizer_fails_at_build_with_typed_error() {
        let text = format!(
            "{SAMPLE}\n[regularizer]\nkind = \"elastic_net\"\nl1_ratio = 1.0\n"
        );
        let cfg = ExperimentConfig::from_toml(&text).unwrap(); // parse is lenient
        let data = crate::data::cov_like(50, 4, 0.1, 1);
        let err = cfg.trainer(&data).build().unwrap_err();
        assert!(matches!(err, Error::InvalidRegularizer { .. }), "{err}");
    }

    #[test]
    fn regularized_config_builds_a_running_session() {
        let text = format!(
            "{SAMPLE}\n[regularizer]\nkind = \"l1\"\nepsilon = 0.5\n"
        )
        .replace("kind = \"hinge\"", "kind = \"squared\"");
        let cfg = ExperimentConfig::from_toml(&text).unwrap();
        let data = crate::data::cov_like(60, 5, 0.1, 2);
        let mut session = cfg.trainer(&data).build().unwrap();
        assert_eq!(session.regularizer(), RegularizerKind::L1 { epsilon: 0.5 });
        let mut algo = cfg.algorithm.instantiate();
        let tr = session.run(algo.as_mut(), Budget::rounds(2)).unwrap();
        assert!(tr.rows.last().unwrap().gap >= -1e-9);
        session.shutdown();
    }

    #[test]
    fn all_algorithms_parse() {
        for (name, extra) in [
            ("cocoa", "h = 10"),
            ("cocoa_plus", "h = 10"),
            ("minibatch_cd", "h = 10\nbeta_b = 2.0"),
            ("minibatch_sgd", "h = 10"),
            ("local_sgd", "h = 10\nbeta = 1.0"),
            ("naive_cd", ""),
            ("naive_sgd", ""),
            ("one_shot_avg", ""),
        ] {
            let text = format!(
                "lambda = 0.1\n[dataset]\nkind = \"cov_like\"\nn = 10\nd = 2\n\
                 [partition]\nk = 2\n[algorithm]\nname = \"{name}\"\n{extra}\n\
                 [loss]\nkind = \"hinge\"\n[run]\nrounds = 1\n"
            );
            let cfg = ExperimentConfig::from_toml(&text).unwrap();
            assert_eq!(cfg.algorithm.name(), name);
        }
    }

    #[test]
    fn unknown_fields_give_useful_errors() {
        let bad_loss = SAMPLE.replace("kind = \"hinge\"", "kind = \"l0\"");
        assert!(ExperimentConfig::from_toml(&bad_loss).is_err());
        let bad_alg = SAMPLE.replace("name = \"cocoa\"", "name = \"adamw\"");
        assert!(ExperimentConfig::from_toml(&bad_alg).is_err());
        let no_lambda = SAMPLE.replace("lambda = 1e-4", "");
        assert!(ExperimentConfig::from_toml(&no_lambda).is_err());
    }

    #[test]
    fn toml_to_trainer_builds_a_running_session() {
        let cfg = ExperimentConfig::from_toml(SAMPLE).unwrap();
        let data = crate::data::cov_like(100, 6, 0.1, 1);
        let mut session = cfg.trainer(&data).build().unwrap();
        let mut algo = cfg.algorithm.instantiate();
        assert_eq!(algo.name(), cfg.algorithm.name());
        assert_eq!(algo.h(), cfg.algorithm.h());
        assert_eq!(algo.beta(), cfg.algorithm.beta());
        let tr = session.run(algo.as_mut(), Budget::rounds(2)).unwrap();
        assert_eq!(tr.algorithm, "cocoa");
        assert_eq!(tr.rows.last().unwrap().round, 2);
        session.shutdown();
    }

    #[test]
    fn smoothed_hinge_gamma_flows_through() {
        let text = SAMPLE.replace(
            "kind = \"hinge\"",
            "kind = \"smoothed_hinge\"\ngamma = 0.25",
        );
        let cfg = ExperimentConfig::from_toml(&text).unwrap();
        assert_eq!(cfg.loss, LossKind::SmoothedHinge { gamma: 0.25 });
    }
}
