//! The paper's convergence theory, computable: Proposition 1's Θ, Lemma 3's
//! σ_min, and Theorem 2's geometric rate. Tests and the theory-validation
//! harness compare measured convergence against these quantities.

use crate::data::{Dataset, Partition};

/// Proposition 1: the local geometric improvement of LOCALSDCA after H
/// steps on a block of size at most `n_max` (`~n` in the paper), for
/// `(1/gamma)`-smooth losses:
/// `Theta = (1 - (lambda n gamma)/(1 + lambda n gamma) * 1/~n)^H`.
pub fn theta_local_sdca(h: usize, lambda: f64, n: usize, gamma: f64, n_max: usize) -> f64 {
    assert!(n_max >= 1);
    let lng = lambda * n as f64 * gamma;
    let per_step = 1.0 - (lng / (1.0 + lng)) / n_max as f64;
    per_step.powi(h as i32)
}

/// Theorem 2: per-round contraction factor of the dual suboptimality,
/// `1 - (1 - Theta) * (1/K) * (lambda n gamma)/(sigma + lambda n gamma)`.
pub fn theorem2_rate(theta: f64, k: usize, lambda: f64, n: usize, gamma: f64, sigma: f64) -> f64 {
    let lng = lambda * n as f64 * gamma;
    1.0 - (1.0 - theta) * (1.0 / k as f64) * (lng / (sigma + lng))
}

/// Rounds predicted by Theorem 2 to shrink the dual suboptimality by
/// `target` (e.g. 1e-3), starting from `D(a*) - D(0) <= 1`.
pub fn theorem2_rounds(rate: f64, target: f64) -> f64 {
    assert!(rate > 0.0 && rate < 1.0);
    target.ln() / rate.ln()
}

/// Lemma 3's partition-correlation constant
/// `sigma_min = max_a lambda^2 n^2 (sum_k ||A_[k] a_[k]||^2 - ||A a||^2) / ||a||^2`,
/// estimated by shifted power iteration on the symmetric operator
/// `M a = lambda^2 n^2 (blockdiag(A_k^T A_k) - A^T A) a`, which in data
/// space reduces to `(M a)_i = x_i . (z_{k(i)} - z)` with
/// `z_b = sum_{j in b} a_j x_j`, `z = sum_b z_b` (the lambda n factors
/// cancel against A's 1/(lambda n) scaling).
///
/// The shift `c = ~n` keeps the iterated operator PSD (Lemma 3 gives
/// `-~n <= eigs(M) <= ~n`), so the dominant eigenvalue of `M + cI` is
/// `sigma_min + c`.
pub fn sigma_min_estimate(data: &Dataset, partition: &Partition, iters: usize, seed: u64) -> f64 {
    let n = data.n();
    assert_eq!(n, partition.n());
    let d = data.d();
    let k = partition.k();
    let shift = partition.n_max() as f64;

    let locate = partition.locate();
    let mut rng = crate::util::Rng::seed_from_u64(seed);
    let mut v: Vec<f64> = (0..n).map(|_| rng.gen_f64() - 0.5).collect();
    normalize(&mut v);

    let mut eig = shift;
    let mut z_blocks = vec![vec![0.0; d]; k];
    for _ in 0..iters {
        // z_b = sum_{j in b} v_j x_j ; z = sum_b z_b
        for zb in z_blocks.iter_mut() {
            zb.iter_mut().for_each(|x| *x = 0.0);
        }
        for (j, &vj) in v.iter().enumerate() {
            if vj != 0.0 {
                let b = locate[j].0 as usize;
                data.features.add_row_scaled(j, vj, &mut z_blocks[b]);
            }
        }
        let mut z = vec![0.0; d];
        for zb in &z_blocks {
            for (zi, &zbi) in z.iter_mut().zip(zb) {
                *zi += zbi;
            }
        }
        // (M + shift I) v
        let mut next = vec![0.0; n];
        for i in 0..n {
            let b = locate[i].0 as usize;
            let diff: f64 = {
                // x_i . (z_b - z) without materializing the difference
                data.features.row_dot(i, &z_blocks[b]) - data.features.row_dot(i, &z)
            };
            next[i] = diff + shift * v[i];
        }
        eig = norm(&next);
        if eig == 0.0 {
            return 0.0;
        }
        for (vi, ni) in v.iter_mut().zip(&next) {
            *vi = ni / eig;
        }
    }
    (eig - shift).max(0.0)
}

fn norm(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>().sqrt()
}

fn normalize(v: &mut [f64]) {
    let nv = norm(v);
    if nv > 0.0 {
        v.iter_mut().for_each(|x| *x /= nv);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{cov_like, orthogonal_blocks, PartitionStrategy};

    #[test]
    fn theta_limits() {
        // H = 0: no progress, Theta = 1. H -> inf: Theta -> 0.
        assert_eq!(theta_local_sdca(0, 0.1, 100, 1.0, 25), 1.0);
        assert!(theta_local_sdca(100_000, 0.1, 100, 1.0, 25) < 1e-6);
        // more steps always helps
        let t1 = theta_local_sdca(10, 0.1, 100, 1.0, 25);
        let t2 = theta_local_sdca(20, 0.1, 100, 1.0, 25);
        assert!(t2 < t1);
    }

    #[test]
    fn theorem2_k1_recovers_theta() {
        // K = 1 with sigma = 0 (Lemma 3): rate = Theta exactly.
        let theta = theta_local_sdca(50, 0.1, 100, 1.0, 100);
        let rate = theorem2_rate(theta, 1, 0.1, 100, 1.0, 0.0);
        assert!((rate - theta).abs() < 1e-12);
    }

    #[test]
    fn theorem2_rate_degrades_with_k_and_sigma() {
        let theta = 0.5;
        let r1 = theorem2_rate(theta, 1, 0.1, 100, 1.0, 0.0);
        let r4 = theorem2_rate(theta, 4, 0.1, 100, 1.0, 0.0);
        let r4s = theorem2_rate(theta, 4, 0.1, 100, 1.0, 50.0);
        assert!(r1 < r4 && r4 < r4s && r4s < 1.0);
    }

    #[test]
    fn sigma_zero_for_orthogonal_partition() {
        let k = 3;
        let data = orthogonal_blocks(k, 10, 4, 1);
        let blocks: Vec<Vec<u32>> = (0..k)
            .map(|b| ((b * 10) as u32..(b * 10 + 10) as u32).collect())
            .collect();
        let part = Partition::from_blocks(blocks, data.n());
        let sigma = sigma_min_estimate(&data, &part, 60, 2);
        assert!(sigma < 1e-6, "sigma = {sigma} should vanish");
    }

    #[test]
    fn sigma_bounds_of_lemma3() {
        let data = cov_like(90, 8, 0.1, 3);
        let part = Partition::new(PartitionStrategy::Contiguous, 90, 3, 0);
        let sigma = sigma_min_estimate(&data, &part, 80, 4);
        assert!(sigma >= 0.0);
        assert!(sigma <= part.n_max() as f64 + 1e-6, "sigma = {sigma}");
        // correlated data split across workers must have sigma > 0
        assert!(sigma > 1e-3, "sigma = {sigma} unexpectedly tiny");
    }

    #[test]
    fn sigma_zero_for_single_block() {
        let data = cov_like(40, 6, 0.1, 5);
        let part = Partition::new(PartitionStrategy::Contiguous, 40, 1, 0);
        let sigma = sigma_min_estimate(&data, &part, 60, 6);
        assert!(sigma < 1e-8, "K=1 must give sigma_min = 0, got {sigma}");
    }

    #[test]
    fn rounds_prediction_monotone() {
        let fast = theorem2_rounds(0.5, 1e-3);
        let slow = theorem2_rounds(0.9, 1e-3);
        assert!(fast < slow);
        assert!(fast > 0.0);
    }
}
