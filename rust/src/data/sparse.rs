//! CSR sparse feature matrix — the rcv1-regime storage (n >> d, ~0.1% nnz).
//!
//! Since PR 9 the index/value arrays live behind a private [`Storage`]
//! enum: either owned `Vec`s (the classic in-memory path) or an
//! `mmap`-backed shard section (see [`crate::data::mmap`]). Every accessor
//! returns plain slices either way, so the unchecked gather kernels, the
//! solvers, and the coordinator are storage-agnostic — and because the
//! bytes are identical, so are the trajectories.
//!
//! ```
//! use cocoa::data::CsrMatrix;
//!
//! let m = CsrMatrix::from_triplets(2, 3, &[(0, 0, 1.0), (0, 2, 2.0), (1, 1, 3.0)]);
//! let (idx, val) = m.row_view(0);
//! assert_eq!((idx, val), (&[0u32, 2][..], &[1.0, 2.0][..]));
//! assert_eq!(m.row_dot(1, &[0.0, 10.0, 0.0]), 30.0);
//! ```

use crate::kernels;

use super::mmap::MappedCsr;

/// Where a matrix's index/value arrays live. Private: constructors
/// validate the CSR invariants once (indices strictly increasing within a
/// row, every `index < cols`), and nothing can break them afterwards.
#[derive(Debug, Clone)]
enum Storage {
    /// Ordinary heap vectors (from_triplets, subset, loaders).
    Owned { indices: Vec<u32>, values: Vec<f64> },
    /// Read-only mmap'ed shard sections, verified at open
    /// (checksums + the same CSR invariants) by `ShardSet::open_shard`.
    Mapped(MappedCsr),
}

impl Storage {
    #[inline]
    fn indices(&self) -> &[u32] {
        match self {
            Storage::Owned { indices, .. } => indices,
            Storage::Mapped(m) => m.indices(),
        }
    }

    #[inline]
    fn values(&self) -> &[f64] {
        match self {
            Storage::Owned { values, .. } => values,
            Storage::Mapped(m) => m.values(),
        }
    }
}

/// Compressed sparse row matrix. `indptr` has `rows + 1` entries;
/// row `i`'s entries live in `indices/values[indptr[i]..indptr[i+1]]`.
///
/// The storage fields are private on purpose: every constructor validates
/// `index < cols`, and nothing can break that afterwards — which is what
/// lets the row accessors run the *unchecked* gather kernels from
/// [`crate::kernels`] soundly (no per-element bounds check in the SDCA
/// inner loop). Read access goes through [`CsrMatrix::row_view`] and
/// friends. The same soundness contract is re-established for mapped
/// shards by `ShardSet::open_shard`'s streaming verification — see
/// `docs/DATA.md`.
#[derive(Debug, Clone)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    indptr: Vec<usize>,
    storage: Storage,
}

/// Logical equality: same shape and the same stored entries, regardless
/// of whether the entries are owned or mapped.
impl PartialEq for CsrMatrix {
    fn eq(&self, other: &Self) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && self.indptr == other.indptr
            && self.storage.indices() == other.storage.indices()
            && self.storage.values() == other.storage.values()
    }
}

impl CsrMatrix {
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        triplets: &[(usize, u32, f64)],
    ) -> Self {
        let mut by_row: Vec<Vec<(u32, f64)>> = vec![Vec::new(); rows];
        for &(r, c, v) in triplets {
            assert!(r < rows && (c as usize) < cols, "triplet out of bounds");
            by_row[r].push((c, v));
        }
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::with_capacity(triplets.len());
        let mut values = Vec::with_capacity(triplets.len());
        indptr.push(0);
        for row in &mut by_row {
            row.sort_unstable_by_key(|&(c, _)| c);
            for &(c, v) in row.iter() {
                indices.push(c);
                values.push(v);
            }
            indptr.push(indices.len());
        }
        CsrMatrix { rows, cols, indptr, storage: Storage::Owned { indices, values } }
    }

    /// Owned matrix from parts whose CSR invariants the caller has
    /// already verified (the shard open path, after checksum +
    /// invariant streaming checks).
    pub(crate) fn from_validated_parts(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f64>,
    ) -> Self {
        debug_assert_eq!(indptr.len(), rows + 1);
        debug_assert_eq!(indices.len(), values.len());
        CsrMatrix { rows, cols, indptr, storage: Storage::Owned { indices, values } }
    }

    /// Mapped matrix over verified shard sections. The caller
    /// (`ShardSet::open_shard`) has checked the invariants against the
    /// very bytes now behind the mapping.
    pub(crate) fn from_mapped(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        mapped: MappedCsr,
    ) -> Self {
        debug_assert_eq!(indptr.len(), rows + 1);
        CsrMatrix { rows, cols, indptr, storage: Storage::Mapped(mapped) }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Stored entries (the CSR nnz).
    pub fn nnz(&self) -> usize {
        *self.indptr.last().expect("indptr has rows + 1 entries")
    }

    #[inline]
    pub fn row_range(&self, i: usize) -> std::ops::Range<usize> {
        self.indptr[i]..self.indptr[i + 1]
    }

    /// Row `i` as `(indices, values)` slices — one indptr fetch for both,
    /// the shape the fused inner-loop kernels consume. On mapped storage
    /// this also feeds the residency accounting that keeps a shard's
    /// resident pages bounded (see [`crate::data::mmap`]).
    #[inline]
    pub fn row_view(&self, i: usize) -> (&[u32], &[f64]) {
        let r = self.row_range(i);
        match &self.storage {
            Storage::Owned { indices, values } => (&indices[r.clone()], &values[r]),
            Storage::Mapped(m) => {
                // 4 index bytes + 8 value bytes per entry
                m.note_touched((r.end - r.start) * 12);
                (&m.indices()[r.clone()], &m.values()[r])
            }
        }
    }

    #[inline]
    pub fn row_dot(&self, i: usize, w: &[f64]) -> f64 {
        assert!(w.len() >= self.cols, "row_dot target shorter than cols");
        let (idx, val) = self.row_view(i);
        // SAFETY: constructors validate index < cols, fields are private,
        // and w.len() >= cols was just checked.
        unsafe { kernels::sparse_dot_unchecked(idx, val, w) }
    }

    #[inline]
    pub fn add_row_scaled(&self, i: usize, coef: f64, out: &mut [f64]) {
        assert!(out.len() >= self.cols, "add_row_scaled target shorter than cols");
        let (idx, val) = self.row_view(i);
        // SAFETY: as in `row_dot` — index < cols <= out.len().
        unsafe { kernels::sparse_axpy_unchecked(idx, val, coef, out) }
    }

    pub fn row_norm_sq(&self, i: usize) -> f64 {
        let r = self.row_range(i);
        kernels::sparse_norm_sq(&self.storage.values()[r])
    }

    /// In-place row scale. Only owned storage is mutable: mapped shards
    /// are read-only by design (normalize *before* sharding — the shard
    /// writer stores the final values and norms).
    pub fn scale_row(&mut self, i: usize, s: f64) {
        let r = self.row_range(i);
        match &mut self.storage {
            Storage::Owned { values, .. } => kernels::scale_in_place(&mut values[r], s),
            Storage::Mapped(_) => panic!(
                "scale_row on an mmap-backed (read-only) shard; \
                 normalize before sharding"
            ),
        }
    }

    pub fn row_nnz(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i]
    }

    pub fn subset(&self, idx: &[u32]) -> CsrMatrix {
        let src_indices = self.storage.indices();
        let src_values = self.storage.values();
        let mut indptr = Vec::with_capacity(idx.len() + 1);
        let nnz: usize = idx.iter().map(|&i| self.row_nnz(i as usize)).sum();
        let mut indices = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        indptr.push(0);
        for &i in idx {
            let r = self.row_range(i as usize);
            indices.extend_from_slice(&src_indices[r.clone()]);
            values.extend_from_slice(&src_values[r]);
            indptr.push(indices.len());
        }
        CsrMatrix {
            rows: idx.len(),
            cols: self.cols,
            indptr,
            storage: Storage::Owned { indices, values },
        }
    }

    /// Append CSR rows in place (continuous training). `indptr` is the
    /// batch-local pointer array (`rows + 1` entries starting at 0). The
    /// same invariants every constructor enforces are re-validated here —
    /// strictly increasing indices within a row, every `index < cols` —
    /// because appended rows feed the same unchecked gather kernels.
    /// Mapped storage is materialized to owned vectors first: the shard
    /// file on disk stays immutable (see `docs/DATA.md`); growing a
    /// mapped block trades its page-residency bound for mutability.
    pub(crate) fn append_csr_rows(
        &mut self,
        indptr: &[usize],
        indices: &[u32],
        values: &[f64],
    ) -> Result<(), String> {
        if indptr.is_empty() || indptr[0] != 0 {
            return Err("append indptr must start at 0".into());
        }
        let nnz = *indptr.last().expect("checked non-empty");
        if nnz != indices.len() || indices.len() != values.len() {
            return Err(format!(
                "append arrays disagree: indptr says {} entries, {} indices, {} values",
                nnz,
                indices.len(),
                values.len()
            ));
        }
        for win in indptr.windows(2) {
            if win[1] < win[0] {
                return Err("append indptr must be non-decreasing".into());
            }
            let row = &indices[win[0]..win[1]];
            for pair in row.windows(2) {
                if pair[1] <= pair[0] {
                    return Err("append indices must be strictly increasing within a row".into());
                }
            }
            if let Some(&last) = row.last() {
                if last as usize >= self.cols {
                    return Err(format!("append index {} >= cols {}", last, self.cols));
                }
            }
        }
        // materialize mapped storage: appends are an owned-memory affair
        if let Storage::Mapped(m) = &self.storage {
            self.storage = Storage::Owned {
                indices: m.indices().to_vec(),
                values: m.values().to_vec(),
            };
        }
        let (own_indices, own_values) = match &mut self.storage {
            Storage::Owned { indices, values } => (indices, values),
            Storage::Mapped(_) => unreachable!("materialized above"),
        };
        let base = *self.indptr.last().expect("indptr has rows + 1 entries");
        own_indices.extend_from_slice(indices);
        own_values.extend_from_slice(values);
        self.indptr.extend(indptr[1..].iter().map(|p| base + p));
        self.rows += indptr.len() - 1;
        Ok(())
    }

    /// Sorted unique columns with at least one stored entry — the shard's
    /// column-touch set. A worker's local updates can only move `w` on
    /// these columns, so the inner loop's delta extraction walks this set
    /// instead of all `cols` (rcv1-regime shards touch a fraction of the
    /// feature space).
    pub fn touched_cols(&self) -> Vec<u32> {
        let mut seen = vec![false; self.cols];
        for &c in self.storage.indices() {
            seen[c as usize] = true;
        }
        let mut cols: Vec<u32> = Vec::new();
        for (c, hit) in seen.iter().enumerate() {
            if *hit {
                cols.push(c as u32);
            }
        }
        cols
    }

    /// Dense expansion (tests / PJRT marshalling of small blocks only).
    pub fn to_dense(&self) -> super::DenseMatrix {
        let mut m = super::DenseMatrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (idx, val) = self.row_view(i);
            for (c, v) in idx.iter().zip(val) {
                m.row_mut(i)[*c as usize] = *v;
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrMatrix {
        CsrMatrix::from_triplets(
            3,
            4,
            &[(0, 1, 2.0), (0, 3, 1.0), (2, 0, -1.0), (2, 2, 0.5)],
        )
    }

    #[test]
    fn row_dot_skips_zeros() {
        let m = sample();
        let w = [1.0, 10.0, 100.0, 1000.0];
        assert_eq!(m.row_dot(0, &w), 20.0 + 1000.0);
        assert_eq!(m.row_dot(1, &w), 0.0); // empty row
        assert_eq!(m.row_dot(2, &w), -1.0 + 50.0);
    }

    #[test]
    fn add_row_scaled_scatter() {
        let m = sample();
        let mut out = vec![0.0; 4];
        m.add_row_scaled(2, 2.0, &mut out);
        assert_eq!(out, vec![-2.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn triplets_sorted_within_row() {
        let m = CsrMatrix::from_triplets(1, 3, &[(0, 2, 1.0), (0, 0, 2.0)]);
        assert_eq!(m.row_view(0).0, &[0, 2]);
        assert_eq!(m.row_view(0).1, &[2.0, 1.0]);
    }

    #[test]
    fn subset_and_dense_roundtrip() {
        let m = sample();
        let s = m.subset(&[2, 0]);
        let d = s.to_dense();
        assert_eq!(d.row(0), &[-1.0, 0.0, 0.5, 0.0]);
        assert_eq!(d.row(1), &[0.0, 2.0, 0.0, 1.0]);
    }

    #[test]
    fn norms() {
        let m = sample();
        assert!((m.row_norm_sq(0) - 5.0).abs() < 1e-12);
        assert_eq!(m.row_norm_sq(1), 0.0);
    }

    #[test]
    fn touched_cols_is_the_sorted_union() {
        let m = sample();
        assert_eq!(m.touched_cols(), vec![0, 1, 2, 3]);
        let s = m.subset(&[0, 1]); // rows 0 (cols 1, 3) and 1 (empty)
        assert_eq!(s.touched_cols(), vec![1, 3]);
        let empty = CsrMatrix::from_triplets(2, 5, &[]);
        assert!(empty.touched_cols().is_empty());
    }

    #[test]
    fn row_view_matches_ranges() {
        let m = sample();
        let (idx, val) = m.row_view(0);
        assert_eq!(idx, &[1, 3]);
        assert_eq!(val, &[2.0, 1.0]);
        assert_eq!(m.row_view(1).0.len(), 0);
        assert_eq!(m.nnz(), 4);
        assert_eq!((m.rows(), m.cols()), (3, 4));
    }

    #[test]
    fn logical_equality_ignores_storage_backing() {
        let a = sample();
        let b = CsrMatrix::from_validated_parts(
            3,
            4,
            vec![0, 2, 2, 4],
            vec![1, 3, 0, 2],
            vec![2.0, 1.0, -1.0, 0.5],
        );
        assert_eq!(a, b);
        let c = CsrMatrix::from_triplets(3, 4, &[(0, 1, 2.0)]);
        assert_ne!(a, c);
    }
}
