//! CSR sparse feature matrix — the rcv1-regime storage (n >> d, ~0.1% nnz).

/// Compressed sparse row matrix. `indptr` has `rows + 1` entries;
/// row `i`'s entries live in `indices/values[indptr[i]..indptr[i+1]]`.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub values: Vec<f64>,
}

impl CsrMatrix {
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        triplets: &[(usize, u32, f64)],
    ) -> Self {
        let mut by_row: Vec<Vec<(u32, f64)>> = vec![Vec::new(); rows];
        for &(r, c, v) in triplets {
            assert!(r < rows && (c as usize) < cols, "triplet out of bounds");
            by_row[r].push((c, v));
        }
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::with_capacity(triplets.len());
        let mut values = Vec::with_capacity(triplets.len());
        indptr.push(0);
        for row in &mut by_row {
            row.sort_unstable_by_key(|&(c, _)| c);
            for &(c, v) in row.iter() {
                indices.push(c);
                values.push(v);
            }
            indptr.push(indices.len());
        }
        CsrMatrix { rows, cols, indptr, indices, values }
    }

    #[inline]
    pub fn row_range(&self, i: usize) -> std::ops::Range<usize> {
        self.indptr[i]..self.indptr[i + 1]
    }

    #[inline]
    pub fn row_dot(&self, i: usize, w: &[f64]) -> f64 {
        let r = self.row_range(i);
        let mut s = 0.0;
        for (idx, val) in self.indices[r.clone()].iter().zip(&self.values[r]) {
            s += val * w[*idx as usize];
        }
        s
    }

    #[inline]
    pub fn add_row_scaled(&self, i: usize, coef: f64, out: &mut [f64]) {
        let r = self.row_range(i);
        for (idx, val) in self.indices[r.clone()].iter().zip(&self.values[r]) {
            out[*idx as usize] += coef * val;
        }
    }

    pub fn row_norm_sq(&self, i: usize) -> f64 {
        self.values[self.row_range(i)].iter().map(|v| v * v).sum()
    }

    pub fn scale_row(&mut self, i: usize, s: f64) {
        let r = self.row_range(i);
        for v in &mut self.values[r] {
            *v *= s;
        }
    }

    pub fn row_nnz(&self, i: usize) -> usize {
        self.indptr[i + 1] - self.indptr[i]
    }

    pub fn subset(&self, idx: &[u32]) -> CsrMatrix {
        let mut indptr = Vec::with_capacity(idx.len() + 1);
        let nnz: usize = idx.iter().map(|&i| self.row_nnz(i as usize)).sum();
        let mut indices = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        indptr.push(0);
        for &i in idx {
            let r = self.row_range(i as usize);
            indices.extend_from_slice(&self.indices[r.clone()]);
            values.extend_from_slice(&self.values[r]);
            indptr.push(indices.len());
        }
        CsrMatrix { rows: idx.len(), cols: self.cols, indptr, indices, values }
    }

    /// Dense expansion (tests / PJRT marshalling of small blocks only).
    pub fn to_dense(&self) -> super::DenseMatrix {
        let mut m = super::DenseMatrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let r = self.row_range(i);
            for (idx, val) in self.indices[r.clone()].iter().zip(&self.values[r]) {
                m.row_mut(i)[*idx as usize] = *val;
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrMatrix {
        CsrMatrix::from_triplets(
            3,
            4,
            &[(0, 1, 2.0), (0, 3, 1.0), (2, 0, -1.0), (2, 2, 0.5)],
        )
    }

    #[test]
    fn row_dot_skips_zeros() {
        let m = sample();
        let w = [1.0, 10.0, 100.0, 1000.0];
        assert_eq!(m.row_dot(0, &w), 20.0 + 1000.0);
        assert_eq!(m.row_dot(1, &w), 0.0); // empty row
        assert_eq!(m.row_dot(2, &w), -1.0 + 50.0);
    }

    #[test]
    fn add_row_scaled_scatter() {
        let m = sample();
        let mut out = vec![0.0; 4];
        m.add_row_scaled(2, 2.0, &mut out);
        assert_eq!(out, vec![-2.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn triplets_sorted_within_row() {
        let m = CsrMatrix::from_triplets(1, 3, &[(0, 2, 1.0), (0, 0, 2.0)]);
        assert_eq!(m.indices, vec![0, 2]);
        assert_eq!(m.values, vec![2.0, 1.0]);
    }

    #[test]
    fn subset_and_dense_roundtrip() {
        let m = sample();
        let s = m.subset(&[2, 0]);
        let d = s.to_dense();
        assert_eq!(d.row(0), &[-1.0, 0.0, 0.5, 0.0]);
        assert_eq!(d.row(1), &[0.0, 2.0, 0.0, 1.0]);
    }

    #[test]
    fn norms() {
        let m = sample();
        assert!((m.row_norm_sq(0) - 5.0).abs() < 1e-12);
        assert_eq!(m.row_norm_sq(1), 0.0);
    }
}
