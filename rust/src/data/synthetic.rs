//! Synthetic workload generators matching the paper's dataset regimes.
//!
//! The paper evaluates on cov, rcv1 and imagenet (Table 1) — real corpora
//! we substitute with generators matched in the quantities the algorithms
//! actually respond to: n/d regime, density, label noise, and cross-worker
//! feature correlation (which controls Lemma 3's sigma_min). See DESIGN.md
//! section 2 for the substitution argument.
//!
//! The `*_stream_shards` generators serve the out-of-core path: they
//! write rows straight into an on-disk [`ShardSet`] through the streaming
//! shard writer, so datasets many times larger than RAM-per-worker can be
//! produced with O(d + n) working memory — the `_ooc` perf family and the
//! ci.sh peak-RSS gate are built on them.
//!
//! ```
//! use cocoa::data::rcv1_stream_shards;
//!
//! let dir = std::env::temp_dir().join("cocoa_doc_stream_shards");
//! let _ = std::fs::remove_dir_all(&dir);
//! let set = rcv1_stream_shards(64, 50, 4, 42, 2, &dir).unwrap();
//! assert_eq!((set.n(), set.k()), (64, 2));
//! assert!(set.open_shard(1).unwrap().n() == 32);
//! ```

use std::path::Path;

use crate::error::Error;
use crate::kernels;
use crate::util::Rng;

use super::mmap::{ShardSet, ShardSetWriter};
use super::{CsrMatrix, Dataset, DenseMatrix, Features, PartitionStrategy};

/// Declarative spec used by the config system and the Table-1 harness.
#[derive(Debug, Clone, PartialEq)]
pub struct SyntheticSpec {
    pub name: String,
    pub n: usize,
    pub d: usize,
    /// Stored entries per row (== d when dense).
    pub nnz_per_row: usize,
    /// Fraction of labels flipped after margin-based assignment.
    pub label_noise: f64,
    pub seed: u64,
}

/// Draw labels from a random ground-truth hyperplane, flip a fraction.
fn assign_labels(features: &Features, noise: f64, rng: &mut Rng) -> Vec<f64> {
    let d = features.cols();
    let truth: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    (0..features.rows())
        .map(|i| {
            let margin = features.row_dot(i, &truth);
            let mut y = if margin >= 0.0 { 1.0 } else { -1.0 };
            if rng.gen_bool(noise) {
                y = -y;
            }
            y
        })
        .collect()
}

/// cov-regime: n >> d, fully dense, low dimension (forest-cover style:
/// paper uses n = 522,911, d = 54). Features carry mild common-factor
/// correlation like the original cartographic variables.
pub fn cov_like(n: usize, d: usize, label_noise: f64, seed: u64) -> Dataset {
    let mut rng = Rng::seed_from_u64(seed ^ 0xc0f);
    let mut data = Vec::with_capacity(n * d);
    for _ in 0..n {
        // one latent factor + independent noise => correlated columns
        let factor = rng.normal();
        for j in 0..d {
            let weight = 0.3 + 0.7 * (j as f64 / d.max(1) as f64);
            data.push(weight * factor + rng.normal());
        }
    }
    let features = Features::Dense(DenseMatrix { rows: n, cols: d, data });
    let labels = assign_labels(&features, label_noise, &mut rng);
    let mut ds = Dataset::new(features, labels);
    ds.normalize_rows();
    ds
}

/// rcv1-regime: n >> d, extremely sparse, high dimension (text tf-idf
/// style: paper uses n = 677,399, d = 47,236 at ~0.16% density). Column
/// popularity follows a Zipf-like law, values are positive tf-idf-ish.
pub fn rcv1_like(
    n: usize,
    d: usize,
    nnz_per_row: usize,
    label_noise: f64,
    seed: u64,
) -> Dataset {
    let mut rng = Rng::seed_from_u64(seed ^ 0x2cf1);
    let mut triplets = Vec::with_capacity(n * nnz_per_row);
    let mut cols_seen = std::collections::HashSet::new();
    for i in 0..n {
        cols_seen.clear();
        let row_nnz = 1 + rng.gen_range((2 * nnz_per_row).max(2) - 1);
        for _ in 0..row_nnz {
            // Zipf-ish column draw: squaring a uniform biases toward 0.
            let u = rng.gen_f64();
            let c = (((u * u) * d as f64) as usize % d) as u32;
            if cols_seen.insert(c) {
                let v = rng.gen_range_f64(0.1, 1.0);
                triplets.push((i, c, v));
            }
        }
    }
    let features = Features::Sparse(CsrMatrix::from_triplets(n, d, &triplets));
    let labels = assign_labels(&features, label_noise, &mut rng);
    let mut ds = Dataset::new(features, labels);
    ds.normalize_rows();
    ds
}

/// imagenet-regime: n << d, dense feature vectors (Fisher-vector style:
/// paper uses n = 32,751, d = 160,000). Generated at reduced scale.
pub fn imagenet_like(n: usize, d: usize, label_noise: f64, seed: u64) -> Dataset {
    let mut rng = Rng::seed_from_u64(seed ^ 0x1339);
    let mut data = Vec::with_capacity(n * d);
    for _ in 0..n {
        for _ in 0..d {
            data.push(rng.normal() * 0.5);
        }
    }
    let features = Features::Dense(DenseMatrix { rows: n, cols: d, data });
    let labels = assign_labels(&features, label_noise, &mut rng);
    let mut ds = Dataset::new(features, labels);
    ds.normalize_rows();
    ds
}

/// K blocks with *disjoint feature support*: datapoints on different
/// workers are exactly orthogonal, the sigma_min = 0 case of Lemma 3.
/// Rows are generated contiguously per block so a contiguous partition
/// into K blocks realizes the orthogonality.
pub fn orthogonal_blocks(
    k: usize,
    rows_per_block: usize,
    cols_per_block: usize,
    seed: u64,
) -> Dataset {
    let mut rng = Rng::seed_from_u64(seed ^ 0x0260);
    let n = k * rows_per_block;
    let d = k * cols_per_block;
    let mut triplets = Vec::new();
    for b in 0..k {
        for r in 0..rows_per_block {
            let row = b * rows_per_block + r;
            for c in 0..cols_per_block {
                let col = (b * cols_per_block + c) as u32;
                triplets.push((row, col, rng.normal()));
            }
        }
    }
    let features = Features::Sparse(CsrMatrix::from_triplets(n, d, &triplets));
    let labels = assign_labels(&features, 0.05, &mut rng);
    let mut ds = Dataset::new(features, labels);
    ds.normalize_rows();
    ds
}

/// The streaming core shared by the `*_stream_shards` generators: one
/// row at a time — Zipf-ish sparse columns, tf-idf-ish positive values,
/// a label from the row's margin against a fixed random hyperplane, the
/// standard `||x_i|| <= 1` per-row normalization — pushed straight into
/// the round-robin shard writer. Working memory is the d-dim truth
/// vector plus the writer's O(n) scalar state; the features never exist
/// in memory at once. Fully deterministic in `seed`.
fn stream_shards_core(
    salt: u64,
    n: usize,
    d: usize,
    nnz_per_row: usize,
    label_noise: f64,
    seed: u64,
    k: usize,
    dir: &Path,
) -> Result<ShardSet, Error> {
    let mut rng = Rng::seed_from_u64(seed ^ salt);
    let truth: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let mut writer =
        ShardSetWriter::create(dir, k, PartitionStrategy::RoundRobin, 0, Some(n))?;
    let want = nnz_per_row.min(d).max(1);
    let mut seen = vec![false; d];
    let mut entries: Vec<(u32, f64)> = Vec::with_capacity(want);
    let mut idx_buf: Vec<u32> = Vec::with_capacity(want);
    let mut val_buf: Vec<f64> = Vec::with_capacity(want);
    for _ in 0..n {
        entries.clear();
        // fixed nnz per row => deterministic shard bytes; cap the rejection
        // loop so adversarial shapes (nnz_per_row ~ d) still terminate
        let mut attempts = 0usize;
        while entries.len() < want && attempts < 8 * want + 16 {
            attempts += 1;
            // Zipf-ish column draw: squaring a uniform biases toward 0.
            let u = rng.gen_f64();
            let c = (((u * u) * d as f64) as usize % d) as u32;
            if !seen[c as usize] {
                seen[c as usize] = true;
                entries.push((c, rng.gen_range_f64(0.1, 1.0)));
            }
        }
        for &(c, _) in &entries {
            seen[c as usize] = false;
        }
        entries.sort_unstable_by_key(|&(c, _)| c);
        idx_buf.clear();
        val_buf.clear();
        for &(c, v) in &entries {
            idx_buf.push(c);
            val_buf.push(v);
        }
        let margin: f64 = entries.iter().map(|&(c, v)| v * truth[c as usize]).sum();
        let mut y = if margin >= 0.0 { 1.0 } else { -1.0 };
        if rng.gen_bool(label_noise) {
            y = -y;
        }
        // per-row normalization, exactly Dataset::normalize_rows
        let mut norm_sq = kernels::sparse_norm_sq(&val_buf);
        let norm = norm_sq.sqrt();
        if norm > 1.0 {
            kernels::scale_in_place(&mut val_buf, 1.0 / norm);
            norm_sq = 1.0;
        }
        writer.push_row(&idx_buf, &val_buf, y, norm_sq)?;
    }
    writer.finish(d)
}

/// rcv1-regime out-of-core generator: n >> d text-style sparsity,
/// streamed directly to `k` on-disk shards (see [`stream_shards_core`]'s
/// description on the module). The paper's rcv1 is n = 677,399,
/// d = 47,236 at ~0.16% density; size to taste via `n`/`d`/`nnz_per_row`.
pub fn rcv1_stream_shards(
    n: usize,
    d: usize,
    nnz_per_row: usize,
    seed: u64,
    k: usize,
    dir: impl AsRef<Path>,
) -> Result<ShardSet, Error> {
    stream_shards_core(0x5cf1, n, d, nnz_per_row, 0.05, seed, k, dir.as_ref())
}

/// url-regime out-of-core generator: even higher-dimensional, sparser
/// rows than rcv1 (the url corpus is d ~ 3.2M at ~0.004% density) with
/// noisier labels. Streamed to `k` on-disk shards.
pub fn url_stream_shards(
    n: usize,
    d: usize,
    nnz_per_row: usize,
    seed: u64,
    k: usize,
    dir: impl AsRef<Path>,
) -> Result<ShardSet, Error> {
    stream_shards_core(0x0541, n, d, nnz_per_row, 0.1, seed, k, dir.as_ref())
}

/// kdd-regime out-of-core generator: web-scale n with moderate d (kddb
/// style), the "many cheap rows" end of the out-of-core spectrum.
/// Streamed to `k` on-disk shards.
pub fn kdd_stream_shards(
    n: usize,
    d: usize,
    nnz_per_row: usize,
    seed: u64,
    k: usize,
    dir: impl AsRef<Path>,
) -> Result<ShardSet, Error> {
    stream_shards_core(0x06dd, n, d, nnz_per_row, 0.02, seed, k, dir.as_ref())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cov_like_shape_and_norms() {
        let ds = cov_like(200, 10, 0.1, 1);
        assert_eq!(ds.n(), 200);
        assert_eq!(ds.d(), 10);
        assert!(ds.max_norm_sq() <= 1.0 + 1e-9);
        assert!(ds.labels.iter().all(|&y| y == 1.0 || y == -1.0));
    }

    #[test]
    fn cov_like_deterministic() {
        let a = cov_like(50, 6, 0.0, 7);
        let b = cov_like(50, 6, 0.0, 7);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.features.row_dense(3), b.features.row_dense(3));
        let c = cov_like(50, 6, 0.0, 8);
        assert_ne!(a.features.row_dense(3), c.features.row_dense(3));
    }

    #[test]
    fn rcv1_like_is_sparse() {
        let ds = rcv1_like(300, 1000, 5, 0.1, 2);
        assert!(ds.density() < 0.02, "density {}", ds.density());
        assert!(ds.nnz() > 300); // at least one entry per row on average
        assert!(ds.max_norm_sq() <= 1.0 + 1e-9);
    }

    #[test]
    fn imagenet_like_regime() {
        let ds = imagenet_like(20, 100, 0.0, 3);
        assert!(ds.n() < ds.d());
        assert!(ds.density() > 0.99);
    }

    #[test]
    fn orthogonal_blocks_are_orthogonal() {
        let k = 3;
        let ds = orthogonal_blocks(k, 8, 5, 4);
        // rows from different blocks share no feature support
        let r0 = ds.features.row_dense(0); // block 0
        let r2 = ds.features.row_dense(2 * 8); // block 2
        let dot: f64 = r0.iter().zip(&r2).map(|(a, b)| a * b).sum();
        assert_eq!(dot, 0.0);
    }

    #[test]
    fn stream_generators_are_deterministic_and_bounded() {
        let dir = std::env::temp_dir()
            .join(format!("cocoa_stream_gen_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let a = rcv1_stream_shards(48, 30, 4, 9, 2, dir.join("a")).unwrap();
        let b = rcv1_stream_shards(48, 30, 4, 9, 2, dir.join("b")).unwrap();
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_eq!(
            std::fs::read(a.shard_path(0)).unwrap(),
            std::fs::read(b.shard_path(0)).unwrap(),
            "same seed must produce byte-identical shards"
        );
        let c = rcv1_stream_shards(48, 30, 4, 10, 2, dir.join("c")).unwrap();
        assert_ne!(a.fingerprint(), c.fingerprint());
        // fixed nnz per row, normalized, classification labels
        let shard = a.open_shard(0).unwrap();
        assert_eq!(shard.nnz(), shard.n() * 4);
        assert!(shard.labels.iter().all(|&y| y == 1.0 || y == -1.0));
        for i in 0..shard.n() {
            assert!(shard.norm_sq(i) <= 1.0 + 1e-9);
        }
        // the other regimes share the core; smoke their shapes
        let u = url_stream_shards(24, 200, 3, 1, 2, dir.join("u")).unwrap();
        assert_eq!((u.n(), u.d()), (24, 200));
        let kdd = kdd_stream_shards(30, 16, 2, 1, 3, dir.join("k")).unwrap();
        assert_eq!((kdd.n(), kdd.k()), (30, 3));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn labels_correlate_with_a_separator() {
        let ds = cov_like(400, 8, 0.0, 9);
        let pos = ds.labels.iter().filter(|&&y| y > 0.0).count();
        assert!(pos > 40 && pos < 360, "degenerate label split: {pos}");
    }
}
