//! Synthetic workload generators matching the paper's dataset regimes.
//!
//! The paper evaluates on cov, rcv1 and imagenet (Table 1) — real corpora
//! we substitute with generators matched in the quantities the algorithms
//! actually respond to: n/d regime, density, label noise, and cross-worker
//! feature correlation (which controls Lemma 3's sigma_min). See DESIGN.md
//! section 2 for the substitution argument.

use crate::util::Rng;

use super::{CsrMatrix, Dataset, DenseMatrix, Features};

/// Declarative spec used by the config system and the Table-1 harness.
#[derive(Debug, Clone, PartialEq)]
pub struct SyntheticSpec {
    pub name: String,
    pub n: usize,
    pub d: usize,
    /// Stored entries per row (== d when dense).
    pub nnz_per_row: usize,
    /// Fraction of labels flipped after margin-based assignment.
    pub label_noise: f64,
    pub seed: u64,
}

/// Draw labels from a random ground-truth hyperplane, flip a fraction.
fn assign_labels(features: &Features, noise: f64, rng: &mut Rng) -> Vec<f64> {
    let d = features.cols();
    let truth: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    (0..features.rows())
        .map(|i| {
            let margin = features.row_dot(i, &truth);
            let mut y = if margin >= 0.0 { 1.0 } else { -1.0 };
            if rng.gen_bool(noise) {
                y = -y;
            }
            y
        })
        .collect()
}

/// cov-regime: n >> d, fully dense, low dimension (forest-cover style:
/// paper uses n = 522,911, d = 54). Features carry mild common-factor
/// correlation like the original cartographic variables.
pub fn cov_like(n: usize, d: usize, label_noise: f64, seed: u64) -> Dataset {
    let mut rng = Rng::seed_from_u64(seed ^ 0xc0f);
    let mut data = Vec::with_capacity(n * d);
    for _ in 0..n {
        // one latent factor + independent noise => correlated columns
        let factor = rng.normal();
        for j in 0..d {
            let weight = 0.3 + 0.7 * (j as f64 / d.max(1) as f64);
            data.push(weight * factor + rng.normal());
        }
    }
    let features = Features::Dense(DenseMatrix { rows: n, cols: d, data });
    let labels = assign_labels(&features, label_noise, &mut rng);
    let mut ds = Dataset::new(features, labels);
    ds.normalize_rows();
    ds
}

/// rcv1-regime: n >> d, extremely sparse, high dimension (text tf-idf
/// style: paper uses n = 677,399, d = 47,236 at ~0.16% density). Column
/// popularity follows a Zipf-like law, values are positive tf-idf-ish.
pub fn rcv1_like(
    n: usize,
    d: usize,
    nnz_per_row: usize,
    label_noise: f64,
    seed: u64,
) -> Dataset {
    let mut rng = Rng::seed_from_u64(seed ^ 0x2cf1);
    let mut triplets = Vec::with_capacity(n * nnz_per_row);
    let mut cols_seen = std::collections::HashSet::new();
    for i in 0..n {
        cols_seen.clear();
        let row_nnz = 1 + rng.gen_range((2 * nnz_per_row).max(2) - 1);
        for _ in 0..row_nnz {
            // Zipf-ish column draw: squaring a uniform biases toward 0.
            let u = rng.gen_f64();
            let c = (((u * u) * d as f64) as usize % d) as u32;
            if cols_seen.insert(c) {
                let v = rng.gen_range_f64(0.1, 1.0);
                triplets.push((i, c, v));
            }
        }
    }
    let features = Features::Sparse(CsrMatrix::from_triplets(n, d, &triplets));
    let labels = assign_labels(&features, label_noise, &mut rng);
    let mut ds = Dataset::new(features, labels);
    ds.normalize_rows();
    ds
}

/// imagenet-regime: n << d, dense feature vectors (Fisher-vector style:
/// paper uses n = 32,751, d = 160,000). Generated at reduced scale.
pub fn imagenet_like(n: usize, d: usize, label_noise: f64, seed: u64) -> Dataset {
    let mut rng = Rng::seed_from_u64(seed ^ 0x1339);
    let mut data = Vec::with_capacity(n * d);
    for _ in 0..n {
        for _ in 0..d {
            data.push(rng.normal() * 0.5);
        }
    }
    let features = Features::Dense(DenseMatrix { rows: n, cols: d, data });
    let labels = assign_labels(&features, label_noise, &mut rng);
    let mut ds = Dataset::new(features, labels);
    ds.normalize_rows();
    ds
}

/// K blocks with *disjoint feature support*: datapoints on different
/// workers are exactly orthogonal, the sigma_min = 0 case of Lemma 3.
/// Rows are generated contiguously per block so a contiguous partition
/// into K blocks realizes the orthogonality.
pub fn orthogonal_blocks(
    k: usize,
    rows_per_block: usize,
    cols_per_block: usize,
    seed: u64,
) -> Dataset {
    let mut rng = Rng::seed_from_u64(seed ^ 0x0260);
    let n = k * rows_per_block;
    let d = k * cols_per_block;
    let mut triplets = Vec::new();
    for b in 0..k {
        for r in 0..rows_per_block {
            let row = b * rows_per_block + r;
            for c in 0..cols_per_block {
                let col = (b * cols_per_block + c) as u32;
                triplets.push((row, col, rng.normal()));
            }
        }
    }
    let features = Features::Sparse(CsrMatrix::from_triplets(n, d, &triplets));
    let labels = assign_labels(&features, 0.05, &mut rng);
    let mut ds = Dataset::new(features, labels);
    ds.normalize_rows();
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cov_like_shape_and_norms() {
        let ds = cov_like(200, 10, 0.1, 1);
        assert_eq!(ds.n(), 200);
        assert_eq!(ds.d(), 10);
        assert!(ds.max_norm_sq() <= 1.0 + 1e-9);
        assert!(ds.labels.iter().all(|&y| y == 1.0 || y == -1.0));
    }

    #[test]
    fn cov_like_deterministic() {
        let a = cov_like(50, 6, 0.0, 7);
        let b = cov_like(50, 6, 0.0, 7);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.features.row_dense(3), b.features.row_dense(3));
        let c = cov_like(50, 6, 0.0, 8);
        assert_ne!(a.features.row_dense(3), c.features.row_dense(3));
    }

    #[test]
    fn rcv1_like_is_sparse() {
        let ds = rcv1_like(300, 1000, 5, 0.1, 2);
        assert!(ds.density() < 0.02, "density {}", ds.density());
        assert!(ds.nnz() > 300); // at least one entry per row on average
        assert!(ds.max_norm_sq() <= 1.0 + 1e-9);
    }

    #[test]
    fn imagenet_like_regime() {
        let ds = imagenet_like(20, 100, 0.0, 3);
        assert!(ds.n() < ds.d());
        assert!(ds.density() > 0.99);
    }

    #[test]
    fn orthogonal_blocks_are_orthogonal() {
        let k = 3;
        let ds = orthogonal_blocks(k, 8, 5, 4);
        // rows from different blocks share no feature support
        let r0 = ds.features.row_dense(0); // block 0
        let r2 = ds.features.row_dense(2 * 8); // block 2
        let dot: f64 = r0.iter().zip(&r2).map(|(a, b)| a * b).sum();
        assert_eq!(dot, 0.0);
    }

    #[test]
    fn labels_correlate_with_a_separator() {
        let ds = cov_like(400, 8, 0.0, 9);
        let pos = ds.labels.iter().filter(|&&y| y > 0.0).count();
        assert!(pos > 40 && pos < 360, "degenerate label split: {pos}");
    }
}
