//! Coordinate-block partitioning: how the n dual variables (and their
//! datapoints) are split over the K workers (Section 3's `{I_k}` blocks).
//!
//! The partition is a first-class object because it is *the* quantity the
//! convergence theory depends on: Lemma 3's sigma_min is a property of how
//! correlated data ends up across blocks, and `~n = max_k n_k` enters
//! Proposition 1's Theta.
//!
//! Every strategy emits blocks in **ascending row order** (Random sorts
//! each block after sampling). The out-of-core shard writer relies on
//! this: rows streamed in global order land in their shard in exactly the
//! order `Dataset::subset(&blocks[k])` would produce, which is what makes
//! shard-mode trajectories bit-identical to in-memory ones.
//!
//! ```
//! use cocoa::data::{Partition, PartitionStrategy};
//!
//! let p = Partition::new(PartitionStrategy::RoundRobin, 7, 2, 0);
//! assert_eq!(p.blocks[0], vec![0, 2, 4, 6]);
//! assert!(p.validate().is_ok());
//! ```

use crate::util::Rng;

/// How rows are assigned to workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionStrategy {
    /// Blocks of consecutive rows (Spark-partition-like; default).
    Contiguous,
    /// Row i goes to worker i mod K.
    RoundRobin,
    /// Uniformly random assignment (balanced up to +-1).
    Random,
}

impl PartitionStrategy {
    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "contiguous" => Some(PartitionStrategy::Contiguous),
            "round_robin" => Some(PartitionStrategy::RoundRobin),
            "random" => Some(PartitionStrategy::Random),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PartitionStrategy::Contiguous => "contiguous",
            PartitionStrategy::RoundRobin => "round_robin",
            PartitionStrategy::Random => "random",
        }
    }
}

/// A disjoint cover of `0..n` by K blocks of row indices.
#[derive(Debug, Clone, PartialEq)]
pub struct Partition {
    pub blocks: Vec<Vec<u32>>,
    n: usize,
}

impl Partition {
    pub fn new(strategy: PartitionStrategy, n: usize, k: usize, seed: u64) -> Self {
        assert!(k >= 1 && k <= n.max(1), "need 1 <= K <= n (K={k}, n={n})");
        let blocks = match strategy {
            PartitionStrategy::Contiguous => {
                // Sizes differ by at most 1: first (n % k) blocks get one extra.
                let base = n / k;
                let extra = n % k;
                let mut blocks = Vec::with_capacity(k);
                let mut start = 0u32;
                for b in 0..k {
                    let size = base + usize::from(b < extra);
                    blocks.push((start..start + size as u32).collect());
                    start += size as u32;
                }
                blocks
            }
            PartitionStrategy::RoundRobin => {
                let mut blocks = vec![Vec::with_capacity(n / k + 1); k];
                for i in 0..n as u32 {
                    blocks[(i as usize) % k].push(i);
                }
                blocks
            }
            PartitionStrategy::Random => {
                let mut rng = Rng::seed_from_u64(seed);
                let mut ids: Vec<u32> = (0..n as u32).collect();
                rng.shuffle(&mut ids);
                let base = n / k;
                let extra = n % k;
                let mut blocks = Vec::with_capacity(k);
                let mut cursor = 0;
                for b in 0..k {
                    let size = base + usize::from(b < extra);
                    let mut block: Vec<u32> =
                        ids[cursor..cursor + size].to_vec();
                    block.sort_unstable(); // cache-friendly local order
                    blocks.push(block);
                    cursor += size;
                }
                blocks
            }
        };
        Partition { blocks, n }
    }

    /// Build directly from explicit blocks (tests, custom layouts).
    pub fn from_blocks(blocks: Vec<Vec<u32>>, n: usize) -> Self {
        let p = Partition { blocks, n };
        debug_assert!(p.validate().is_ok());
        p
    }

    pub fn k(&self) -> usize {
        self.blocks.len()
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Size of the largest block — `~n` in Proposition 1.
    pub fn n_max(&self) -> usize {
        self.blocks.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// Checks the disjoint-cover invariant; the coordinator asserts this
    /// at startup and proptests hammer it.
    pub fn validate(&self) -> Result<(), String> {
        let mut seen = vec![false; self.n];
        for (k, block) in self.blocks.iter().enumerate() {
            for &i in block {
                let i = i as usize;
                if i >= self.n {
                    return Err(format!("block {k} contains out-of-range row {i}"));
                }
                if seen[i] {
                    return Err(format!("row {i} appears in multiple blocks"));
                }
                seen[i] = true;
            }
        }
        if let Some(missing) = seen.iter().position(|s| !s) {
            return Err(format!("row {missing} not covered by any block"));
        }
        Ok(())
    }

    /// Map from global row -> (worker, local index).
    pub fn locate(&self) -> Vec<(u32, u32)> {
        let mut loc = vec![(0u32, 0u32); self.n];
        for (k, block) in self.blocks.iter().enumerate() {
            for (local, &i) in block.iter().enumerate() {
                loc[i as usize] = (k as u32, local as u32);
            }
        }
        loc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_covers_with_balanced_sizes() {
        let p = Partition::new(PartitionStrategy::Contiguous, 10, 3, 0);
        assert_eq!(p.k(), 3);
        assert_eq!(p.blocks[0].len(), 4);
        assert_eq!(p.blocks[1].len(), 3);
        assert_eq!(p.blocks[2].len(), 3);
        assert!(p.validate().is_ok());
        assert_eq!(p.n_max(), 4);
    }

    #[test]
    fn round_robin_interleaves() {
        let p = Partition::new(PartitionStrategy::RoundRobin, 7, 2, 0);
        assert_eq!(p.blocks[0], vec![0, 2, 4, 6]);
        assert_eq!(p.blocks[1], vec![1, 3, 5]);
        assert!(p.validate().is_ok());
    }

    #[test]
    fn random_is_balanced_and_seed_stable() {
        let a = Partition::new(PartitionStrategy::Random, 100, 7, 42);
        let b = Partition::new(PartitionStrategy::Random, 100, 7, 42);
        assert_eq!(a, b);
        assert!(a.validate().is_ok());
        assert!(a.n_max() <= 100 / 7 + 1);
        let c = Partition::new(PartitionStrategy::Random, 100, 7, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn validate_catches_overlap_and_gap() {
        let p = Partition { blocks: vec![vec![0, 1], vec![1, 2]], n: 3 };
        assert!(p.validate().unwrap_err().contains("multiple"));
        let p = Partition { blocks: vec![vec![0], vec![2]], n: 3 };
        assert!(p.validate().unwrap_err().contains("not covered"));
    }

    #[test]
    fn locate_inverts_blocks() {
        let p = Partition::new(PartitionStrategy::RoundRobin, 9, 3, 0);
        let loc = p.locate();
        for (i, &(k, local)) in loc.iter().enumerate() {
            assert_eq!(p.blocks[k as usize][local as usize], i as u32);
        }
    }

    #[test]
    fn k_equals_one_is_single_block() {
        let p = Partition::new(PartitionStrategy::Contiguous, 5, 1, 0);
        assert_eq!(p.blocks[0], vec![0, 1, 2, 3, 4]);
    }
}
