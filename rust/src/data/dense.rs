//! Dense row-major feature matrix.

/// Dense `rows x cols` matrix, row-major. The layout is chosen so a row
/// (`x_i`) is one contiguous slice: the SDCA inner loop is a dot and an
/// axpy over that slice.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl DenseMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        DenseMatrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let cols = rows.first().map_or(0, |r| r.len());
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            assert_eq!(r.len(), cols, "ragged rows");
            data.extend_from_slice(r);
        }
        DenseMatrix { rows: rows.len(), cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_dot(&self, i: usize, w: &[f64]) -> f64 {
        dot(self.row(i), w)
    }

    #[inline]
    pub fn add_row_scaled(&self, i: usize, coef: f64, out: &mut [f64]) {
        axpy(coef, self.row(i), out);
    }

    pub fn row_norm_sq(&self, i: usize) -> f64 {
        crate::kernels::dense_norm_sq(self.row(i))
    }

    pub fn scale_row(&mut self, i: usize, s: f64) {
        crate::kernels::scale_in_place(self.row_mut(i), s);
    }

    pub fn subset(&self, idx: &[u32]) -> DenseMatrix {
        let mut data = Vec::with_capacity(idx.len() * self.cols);
        for &i in idx {
            data.extend_from_slice(self.row(i as usize));
        }
        DenseMatrix { rows: idx.len(), cols: self.cols, data }
    }

    /// Flatten to f32 row-major (PJRT literal marshalling).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }
}

/// 8-lane blocked dot product — now a thin re-export of
/// [`crate::kernels::dense_dot`], which owns the blocked reduction (and
/// its bit-exactness contract) for every dense hot path.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    crate::kernels::dense_dot(a, b)
}

/// `out += coef * a`, blocked like [`dot`] — a thin re-export of
/// [`crate::kernels::dense_axpy`].
#[inline]
pub fn axpy(coef: f64, a: &[f64], out: &mut [f64]) {
    crate::kernels::dense_axpy(coef, a, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..13).map(|i| i as f64 * 0.5).collect();
        let b: Vec<f64> = (0..13).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-12);
    }

    #[test]
    fn axpy_accumulates() {
        let a = vec![1.0, 2.0, 3.0];
        let mut out = vec![10.0, 10.0, 10.0];
        axpy(2.0, &a, &mut out);
        assert_eq!(out, vec![12.0, 14.0, 16.0]);
    }

    #[test]
    fn subset_picks_rows() {
        let m = DenseMatrix::from_rows(&[vec![1.0], vec![2.0], vec![3.0]]);
        let s = m.subset(&[2, 1]);
        assert_eq!(s.data, vec![3.0, 2.0]);
    }

    #[test]
    fn row_accessors() {
        let m = DenseMatrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(m.row(1), &[3.0, 4.0]);
        assert_eq!(m.row_dot(0, &[1.0, 1.0]), 3.0);
        assert_eq!(m.row_norm_sq(1), 25.0);
    }
}
