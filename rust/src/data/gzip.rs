//! Minimal gzip (RFC 1952) reader over a from-scratch DEFLATE (RFC 1951)
//! inflater, so `.gz` LibSVM corpora feed the ingesters without adding a
//! compression dependency. Decode only — the repo never writes `.gz`.
//!
//! Scope: exactly what decompressing a dataset needs. All three block
//! types (stored, fixed-Huffman, dynamic-Huffman), concatenated members,
//! the optional header fields (FEXTRA/FNAME/FCOMMENT/FHCRC), and CRC32 +
//! ISIZE verification of every member. The whole stream is inflated into
//! memory up front ([`open_maybe_gz`] hands back a `Cursor`): ingestion
//! is a one-shot offline path, and the streaming sharder's strength —
//! O(n)-scalar peak memory — is about the *parsed* representation, not
//! the text. Decoding is the simple bit-at-a-time canonical-Huffman walk
//! (the `puff.c` construction): a few tens of MB/s, plenty for ingest.

use std::io::{BufRead, Cursor};
use std::path::Path;

/// Does this path name a gzip stream? Extension test only (`.gz`, any
/// case) — both ingesters use it, so `data.svm.gz` works wherever
/// `data.svm` does.
pub(crate) fn is_gz(path: &Path) -> bool {
    path.extension().is_some_and(|e| e.eq_ignore_ascii_case("gz"))
}

/// Open `path` for line-oriented reading, transparently gunzipping when
/// [`is_gz`]. Corrupt gzip data surfaces as `ErrorKind::InvalidData`
/// with the inflater's message.
pub(crate) fn open_maybe_gz(path: &Path) -> std::io::Result<Box<dyn BufRead>> {
    if is_gz(path) {
        let bytes = std::fs::read(path)?;
        let out = gunzip(&bytes)
            .map_err(|m| std::io::Error::new(std::io::ErrorKind::InvalidData, m))?;
        Ok(Box::new(Cursor::new(out)))
    } else {
        Ok(Box::new(std::io::BufReader::new(std::fs::File::open(path)?)))
    }
}

/// Decompress a complete gzip file (one or more concatenated members,
/// per the spec). Every malformed input is a `String` error, never a
/// panic; callers wrap it in their own typed error.
pub(crate) fn gunzip(data: &[u8]) -> Result<Vec<u8>, String> {
    let mut bits = Bits::new(data);
    let mut out = Vec::new();
    loop {
        member(&mut bits, &mut out)?;
        if bits.remaining() == 0 {
            return Ok(out);
        }
    }
}

/// One gzip member: header, deflate stream, CRC32 + ISIZE trailer.
fn member(bits: &mut Bits<'_>, out: &mut Vec<u8>) -> Result<(), String> {
    let h = bits.bytes(10)?;
    if h[0] != 0x1f || h[1] != 0x8b {
        return Err("not a gzip stream (bad magic)".into());
    }
    if h[2] != 8 {
        return Err(format!("unsupported gzip compression method {}", h[2]));
    }
    let flg = h[3];
    if flg & 0xe0 != 0 {
        return Err("reserved gzip FLG bits set".into());
    }
    if flg & 0x04 != 0 {
        let xlen = bits.u16le()? as usize; // FEXTRA
        bits.bytes(xlen)?;
    }
    if flg & 0x08 != 0 {
        bits.skip_cstr()?; // FNAME
    }
    if flg & 0x10 != 0 {
        bits.skip_cstr()?; // FCOMMENT
    }
    if flg & 0x02 != 0 {
        bits.bytes(2)?; // FHCRC over the header — CRC32 below subsumes it
    }
    let start = out.len();
    inflate(bits, out)?;
    bits.align();
    let crc = bits.u32le()?;
    let isize = bits.u32le()?;
    if crc32(&out[start..]) != crc {
        return Err("gzip CRC32 mismatch (corrupt stream)".into());
    }
    if (out.len() - start) as u32 != isize {
        return Err("gzip ISIZE mismatch (corrupt stream)".into());
    }
    Ok(())
}

/// IEEE CRC32 (reflected, poly 0xEDB88320) — the gzip trailer checksum.
/// Bitwise, no table: this path is ingest-only.
pub(crate) fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = !0u32;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB88320 & mask);
        }
    }
    !crc
}

// ---------------------------------------------------------------------------
// DEFLATE (RFC 1951)
// ---------------------------------------------------------------------------

/// LSB-first bit cursor over the member bytes; byte-granular reads
/// require alignment (stored blocks and the trailer re-align per spec).
struct Bits<'a> {
    data: &'a [u8],
    byte: usize,
    bit: u32,
}

impl<'a> Bits<'a> {
    fn new(data: &'a [u8]) -> Bits<'a> {
        Bits { data, byte: 0, bit: 0 }
    }

    fn take(&mut self, n: u32) -> Result<u64, String> {
        let mut v = 0u64;
        for i in 0..n {
            let Some(&b) = self.data.get(self.byte) else {
                return Err("truncated deflate stream".into());
            };
            v |= u64::from((b >> self.bit) & 1) << i;
            self.bit += 1;
            if self.bit == 8 {
                self.bit = 0;
                self.byte += 1;
            }
        }
        Ok(v)
    }

    fn align(&mut self) {
        if self.bit != 0 {
            self.bit = 0;
            self.byte += 1;
        }
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8], String> {
        debug_assert_eq!(self.bit, 0, "byte read while bit-misaligned");
        let end = self
            .byte
            .checked_add(n)
            .filter(|&e| e <= self.data.len())
            .ok_or("truncated gzip stream")?;
        let s = &self.data[self.byte..end];
        self.byte = end;
        Ok(s)
    }

    fn u16le(&mut self) -> Result<u16, String> {
        let b = self.bytes(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32le(&mut self) -> Result<u32, String> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn skip_cstr(&mut self) -> Result<(), String> {
        while self.bytes(1)?[0] != 0 {}
        Ok(())
    }

    fn remaining(&self) -> usize {
        self.data.len() - self.byte.min(self.data.len())
    }
}

/// A canonical Huffman code as (count per length, symbols in canonical
/// order) — decoded bit by bit. Rejects over-subscribed length sets;
/// incomplete sets are legal (the spec allows e.g. a single 1-bit
/// distance code) and surface as a decode error only if the missing
/// codes actually appear.
struct Huffman {
    counts: [u16; 16],
    symbols: Vec<u16>,
}

impl Huffman {
    fn new(lengths: &[u8]) -> Result<Huffman, String> {
        let mut counts = [0u16; 16];
        for &l in lengths {
            if l > 15 {
                return Err(format!("huffman code length {l} > 15"));
            }
            counts[l as usize] += 1;
        }
        counts[0] = 0;
        let mut left = 1i32;
        for len in 1..16 {
            left <<= 1;
            left -= i32::from(counts[len]);
            if left < 0 {
                return Err("over-subscribed huffman code".into());
            }
        }
        let mut offs = [0u16; 16];
        for len in 1..15 {
            offs[len + 1] = offs[len] + counts[len];
        }
        let mut symbols = vec![0u16; lengths.iter().filter(|&&l| l > 0).count()];
        for (sym, &l) in lengths.iter().enumerate() {
            if l > 0 {
                symbols[offs[l as usize] as usize] = sym as u16;
                offs[l as usize] += 1;
            }
        }
        Ok(Huffman { counts, symbols })
    }

    fn decode(&self, bits: &mut Bits<'_>) -> Result<u16, String> {
        let mut code = 0i32;
        let mut first = 0i32;
        let mut index = 0i32;
        for len in 1..16 {
            code |= bits.take(1)? as i32;
            let count = i32::from(self.counts[len]);
            if code - first < count {
                return Ok(self.symbols[(index + code - first) as usize]);
            }
            index += count;
            first = (first + count) << 1;
            code <<= 1;
        }
        Err("invalid huffman code".into())
    }
}

const LEN_BASE: [u16; 29] = [
    3, 4, 5, 6, 7, 8, 9, 10, 11, 13, 15, 17, 19, 23, 27, 31, 35, 43, 51, 59, 67, 83, 99, 115,
    131, 163, 195, 227, 258,
];
const LEN_EXTRA: [u32; 29] = [
    0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4, 4, 4, 5, 5, 5, 5, 0,
];
const DIST_BASE: [u16; 30] = [
    1, 2, 3, 4, 5, 7, 9, 13, 17, 25, 33, 49, 65, 97, 129, 193, 257, 385, 513, 769, 1025, 1537,
    2049, 3073, 4097, 6145, 8193, 12289, 16385, 24577,
];
const DIST_EXTRA: [u32; 30] = [
    0, 0, 0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7, 8, 8, 9, 9, 10, 10, 11, 11, 12, 12,
    13, 13,
];
/// Code-length-code symbol transmission order (RFC 1951 §3.2.7).
const CLEN_ORDER: [usize; 19] =
    [16, 17, 18, 0, 8, 7, 9, 6, 10, 5, 11, 4, 12, 3, 13, 2, 14, 1, 15];

fn inflate(bits: &mut Bits<'_>, out: &mut Vec<u8>) -> Result<(), String> {
    loop {
        let bfinal = bits.take(1)?;
        match bits.take(2)? {
            0 => {
                bits.align();
                let len = bits.u16le()?;
                let nlen = bits.u16le()?;
                if len != !nlen {
                    return Err("stored block LEN/NLEN mismatch".into());
                }
                out.extend_from_slice(bits.bytes(len as usize)?);
            }
            1 => {
                let (lit, dist) = fixed_tables()?;
                block(bits, out, &lit, &dist)?;
            }
            2 => {
                let (lit, dist) = dynamic_tables(bits)?;
                block(bits, out, &lit, &dist)?;
            }
            _ => return Err("reserved deflate block type 3".into()),
        }
        if bfinal == 1 {
            return Ok(());
        }
    }
}

/// The fixed litlen/distance code of RFC 1951 §3.2.6.
fn fixed_tables() -> Result<(Huffman, Huffman), String> {
    let mut lit = [0u8; 288];
    lit[..144].fill(8);
    lit[144..256].fill(9);
    lit[256..280].fill(7);
    lit[280..].fill(8);
    Ok((Huffman::new(&lit)?, Huffman::new(&[5u8; 30])?))
}

/// Decode the HLIT/HDIST/HCLEN header and the run-length-encoded code
/// lengths of a dynamic block.
fn dynamic_tables(bits: &mut Bits<'_>) -> Result<(Huffman, Huffman), String> {
    let hlit = bits.take(5)? as usize + 257;
    let hdist = bits.take(5)? as usize + 1;
    let hclen = bits.take(4)? as usize + 4;
    if hlit > 286 || hdist > 30 {
        return Err(format!("dynamic block declares {hlit} litlen / {hdist} dist codes"));
    }
    let mut cl = [0u8; 19];
    for &sym in CLEN_ORDER.iter().take(hclen) {
        cl[sym] = bits.take(3)? as u8;
    }
    let clh = Huffman::new(&cl)?;
    let mut lengths = vec![0u8; hlit + hdist];
    let mut i = 0;
    while i < lengths.len() {
        let sym = clh.decode(bits)?;
        let (fill, reps) = match sym {
            0..=15 => {
                lengths[i] = sym as u8;
                i += 1;
                continue;
            }
            16 => {
                if i == 0 {
                    return Err("code-length repeat with no previous length".into());
                }
                (lengths[i - 1], 3 + bits.take(2)? as usize)
            }
            17 => (0, 3 + bits.take(3)? as usize),
            _ => (0, 11 + bits.take(7)? as usize), // 18; clh only emits 0..=18
        };
        if i + reps > lengths.len() {
            return Err("code-length repeat overflows the declared count".into());
        }
        lengths[i..i + reps].fill(fill);
        i += reps;
    }
    Ok((Huffman::new(&lengths[..hlit])?, Huffman::new(&lengths[hlit..])?))
}

/// Decode one Huffman-coded block body into `out`. Back-references copy
/// byte by byte so overlapping matches (dist < len) replicate correctly.
fn block(
    bits: &mut Bits<'_>,
    out: &mut Vec<u8>,
    lit: &Huffman,
    dist: &Huffman,
) -> Result<(), String> {
    loop {
        let sym = lit.decode(bits)?;
        if sym < 256 {
            out.push(sym as u8);
        } else if sym == 256 {
            return Ok(());
        } else {
            let s = sym as usize - 257;
            if s >= 29 {
                return Err(format!("invalid length symbol {sym}"));
            }
            let len = LEN_BASE[s] as usize + bits.take(LEN_EXTRA[s])? as usize;
            let dsym = dist.decode(bits)? as usize;
            if dsym >= 30 {
                return Err(format!("invalid distance symbol {dsym}"));
            }
            let d = DIST_BASE[dsym] as usize + bits.take(DIST_EXTRA[dsym])? as usize;
            if d > out.len() {
                return Err("back-reference before output start".into());
            }
            let start = out.len() - d;
            for j in 0..len {
                let b = out[start + j];
                out.push(b);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Test-only gzip writers — the repo never compresses for real; these
// exist so round-trip tests can exercise all three block types without a
// gzip binary in the environment.
// ---------------------------------------------------------------------------

#[cfg(test)]
pub(crate) mod testgz {
    use super::crc32;

    fn header(out: &mut Vec<u8>) {
        // CM=8, no flags, zero MTIME, XFL=0, OS=255 (unknown)
        out.extend_from_slice(&[0x1f, 0x8b, 8, 0, 0, 0, 0, 0, 0, 255]);
    }

    fn trailer(out: &mut Vec<u8>, data: &[u8]) {
        out.extend_from_slice(&crc32(data).to_le_bytes());
        out.extend_from_slice(&(data.len() as u32).to_le_bytes());
    }

    /// LSB-first bit sink; Huffman codes go MSB-first per the spec.
    struct BitWriter {
        bytes: Vec<u8>,
        bit: u32,
    }

    impl BitWriter {
        fn new(bytes: Vec<u8>) -> BitWriter {
            BitWriter { bytes, bit: 0 }
        }

        fn push_bits(&mut self, v: u64, n: u32) {
            for i in 0..n {
                if self.bit == 0 {
                    self.bytes.push(0);
                }
                let last = self.bytes.last_mut().expect("pushed above");
                *last |= (((v >> i) & 1) as u8) << self.bit;
                self.bit = (self.bit + 1) % 8;
            }
        }

        fn push_code(&mut self, code: u32, n: u32) {
            for i in (0..n).rev() {
                self.push_bits(u64::from((code >> i) & 1), 1);
            }
        }

        fn finish(self) -> Vec<u8> {
            self.bytes
        }
    }

    /// Compress with stored (BTYPE=00) blocks only.
    pub(crate) fn gzip_stored(data: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        header(&mut out);
        let mut chunks = data.chunks(0xffff).peekable();
        if data.is_empty() {
            out.extend_from_slice(&[1, 0, 0, 0xff, 0xff]);
        }
        while let Some(c) = chunks.next() {
            // 3 header bits then byte alignment: the header occupies one
            // whole byte whose value is just BFINAL
            out.push(u8::from(chunks.peek().is_none()));
            out.extend_from_slice(&(c.len() as u16).to_le_bytes());
            out.extend_from_slice(&(!(c.len() as u16)).to_le_bytes());
            out.extend_from_slice(c);
        }
        trailer(&mut out, data);
        out
    }

    pub(crate) enum Tok {
        Lit(u8),
        Match { len: usize, dist: usize },
    }

    /// The fixed litlen code of RFC 1951 §3.2.6 as (code, bits).
    fn fixed_code(sym: usize) -> (u32, u32) {
        match sym {
            0..=143 => (0x30 + sym as u32, 8),
            144..=255 => (0x190 + (sym as u32 - 144), 9),
            256..=279 => (sym as u32 - 256, 7),
            _ => (0xc0 + (sym as u32 - 280), 8),
        }
    }

    /// Largest base-table entry not exceeding `v`: (symbol offset, extra).
    fn table_code(bases: &[u16], extras: &[u32], v: usize) -> (usize, u64, u32) {
        let s = bases.iter().rposition(|&b| b as usize <= v).expect("v >= min base");
        (s, (v - bases[s] as usize) as u64, extras[s])
    }

    /// One fixed-Huffman (BTYPE=01) block from an explicit token stream;
    /// returns (gzip bytes, expected decompressed bytes).
    pub(crate) fn gzip_fixed(tokens: &[Tok]) -> (Vec<u8>, Vec<u8>) {
        let mut expect: Vec<u8> = Vec::new();
        let mut head = Vec::new();
        header(&mut head);
        let mut bw = BitWriter::new(head);
        bw.push_bits(1, 1); // BFINAL
        bw.push_bits(1, 2); // fixed
        for t in tokens {
            match *t {
                Tok::Lit(b) => {
                    let (c, n) = fixed_code(b as usize);
                    bw.push_code(c, n);
                    expect.push(b);
                }
                Tok::Match { len, dist } => {
                    let (s, extra, nbits) = table_code(&super::LEN_BASE, &super::LEN_EXTRA, len);
                    let (c, n) = fixed_code(257 + s);
                    bw.push_code(c, n);
                    bw.push_bits(extra, nbits);
                    let (ds, dextra, dnbits) =
                        table_code(&super::DIST_BASE, &super::DIST_EXTRA, dist);
                    bw.push_code(ds as u32, 5);
                    bw.push_bits(dextra, dnbits);
                    let start = expect.len() - dist;
                    for j in 0..len {
                        let b = expect[start + j];
                        expect.push(b);
                    }
                }
            }
        }
        let (c, n) = fixed_code(256);
        bw.push_code(c, n);
        let mut out = bw.finish();
        trailer(&mut out, &expect);
        (out, expect)
    }

    /// One dynamic-Huffman (BTYPE=10) block: every litlen symbol 0..=256
    /// gets a 9-bit code (so canonical code == symbol), plus a single
    /// unused 1-bit distance code — exercising the code-length decoder,
    /// the 16-repeat path, and incomplete distance codes.
    pub(crate) fn gzip_dynamic(data: &[u8]) -> Vec<u8> {
        let mut head = Vec::new();
        header(&mut head);
        let mut bw = BitWriter::new(head);
        bw.push_bits(1, 1); // BFINAL
        bw.push_bits(2, 2); // dynamic
        bw.push_bits(0, 5); // HLIT  = 257
        bw.push_bits(0, 5); // HDIST = 1
        // code-length code: length(9) = 1 bit, length(16) = length(1) = 2
        // bits; canonical codes 9 -> 0, 1 -> 10b, 16 -> 11b. CLEN_ORDER
        // index of symbol 1 is 17, so transmit 18 entries.
        bw.push_bits(18 - 4, 4); // HCLEN
        for &sym in super::CLEN_ORDER.iter().take(18) {
            let l = match sym {
                9 => 1u64,
                16 | 1 => 2,
                _ => 0,
            };
            bw.push_bits(l, 3);
        }
        // litlen lengths: 257 nines = one literal 9 + repeats (42x6 + 1x4)
        bw.push_code(0, 1); // length 9
        for _ in 0..42 {
            bw.push_code(3, 2); // symbol 16
            bw.push_bits(6 - 3, 2);
        }
        bw.push_code(3, 2);
        bw.push_bits(4 - 3, 2);
        bw.push_code(2, 2); // distance code: length 1
        // payload: all codes are 9 bits, code == symbol
        for &b in data {
            bw.push_code(u32::from(b), 9);
        }
        bw.push_code(256, 9);
        let mut out = bw.finish();
        trailer(&mut out, data);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::testgz::{gzip_dynamic, gzip_fixed, gzip_stored, Tok};
    use super::*;

    #[test]
    fn crc32_known_answer() {
        // the standard CRC32 check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn stored_blocks_roundtrip_including_multi_block() {
        for data in [
            Vec::new(),
            b"hello libsvm\n".to_vec(),
            // > 64 KiB forces multiple stored blocks
            (0..70_000u32).map(|i| (i % 251) as u8).collect::<Vec<u8>>(),
        ] {
            assert_eq!(gunzip(&gzip_stored(&data)).unwrap(), data);
        }
    }

    #[test]
    fn fixed_blocks_roundtrip_with_overlapping_matches() {
        let (gz, expect) = gzip_fixed(&[
            Tok::Lit(b'a'),
            Tok::Lit(b'b'),
            Tok::Lit(b'c'),
            // overlapping copy: len > dist replicates the last 3 bytes
            Tok::Match { len: 9, dist: 3 },
            Tok::Lit(0xfe), // a 9-bit literal
            // length and distance both with extra bits
            Tok::Match { len: 13, dist: 5 },
        ]);
        assert_eq!(gunzip(&gz).unwrap(), expect);
        assert!(expect.starts_with(b"abcabcabcabc"));
    }

    #[test]
    fn dynamic_blocks_roundtrip_all_byte_values() {
        let data: Vec<u8> = (0..=255u8).cycle().take(700).collect();
        assert_eq!(gunzip(&gzip_dynamic(&data)).unwrap(), data);
    }

    #[test]
    fn concatenated_members_decode_in_order() {
        let mut gz = gzip_stored(b"first ");
        gz.extend_from_slice(&gzip_dynamic(b"second"));
        assert_eq!(gunzip(&gz).unwrap(), b"first second");
    }

    #[test]
    fn corrupt_streams_are_typed_errors() {
        let good = gzip_stored(b"payload bytes here");
        // bad magic
        let mut bad = good.clone();
        bad[0] = 0x1e;
        assert!(gunzip(&bad).unwrap_err().contains("magic"));
        // flipped payload byte -> CRC mismatch
        let mut bad = good.clone();
        let at = bad.len() - 12; // inside the stored payload
        bad[at] ^= 0x01;
        assert!(gunzip(&bad).unwrap_err().contains("CRC"));
        // truncation
        assert!(gunzip(&good[..good.len() - 6]).unwrap_err().contains("truncated"));
        // reserved block type
        let mut bad = good.clone();
        bad[10] = 0b111; // BFINAL + BTYPE=3
        assert!(gunzip(&bad).unwrap_err().contains("reserved"));
    }

    #[test]
    fn gz_extension_detection_is_case_insensitive() {
        assert!(is_gz(Path::new("data.svm.gz")));
        assert!(is_gz(Path::new("DATA.SVM.GZ")));
        assert!(!is_gz(Path::new("data.svm")));
        assert!(!is_gz(Path::new("gz")));
    }
}
