//! LibSVM-format reader/writer.
//!
//! The paper's datasets (cov, rcv1, imagenet) ship in this format; with a
//! local copy, `[dataset] kind = "libsvm", path = "..."` in the experiment
//! config drops the real corpus into any harness. The writer exists so
//! synthetic datasets can be exported and round-tripped.
//!
//! The reader is hardened against the format's wild variants: `qid:` rank
//! fields and comments (full-line and trailing `# ...`) are accepted,
//! out-of-order feature indices are sorted, and every malformed input —
//! bad labels/indices/values, duplicate indices, 0-based indices,
//! non-finite values — surfaces as the typed
//! [`Error::Libsvm`](crate::error::Error::Libsvm) with a 1-based line
//! number instead of a panic or a stringly error.
//!
//! Labels: when *every* label is one of the classification conventions
//! `{-1, 0, 1, 2}` the file is treated as binary and normalized to
//! `{-1, +1}` (`<= 0` maps to `-1`); any other value anywhere makes the
//! whole file a regression target set and labels pass through untouched —
//! lasso/squared-loss workloads keep their real-valued responses.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::error::Error;

use super::{CsrMatrix, Dataset, Features};

fn bad(line: usize, message: impl Into<String>) -> Error {
    Error::Libsvm { line, message: message.into() }
}

/// Parse a LibSVM file: `label [qid:<q>] idx:val idx:val ... [# comment]`
/// per line, 1-based indices. `d_hint` pre-sizes the column count (pass 0
/// to infer). Malformed input yields the typed
/// [`Error::Libsvm`](crate::error::Error::Libsvm) — see the module docs
/// for exactly what is accepted.
pub fn read_libsvm<P: AsRef<Path>>(path: P, d_hint: usize) -> Result<Dataset, Error> {
    let file = File::open(&path)
        .map_err(|e| bad(0, format!("open {}: {e}", path.as_ref().display())))?;
    let reader = BufReader::new(file);
    let mut labels = Vec::new();
    let mut triplets: Vec<(usize, u32, f64)> = Vec::new();
    let mut max_col: usize = d_hint;
    // per-row duplicate detection without a hash set (offline build):
    // collect the row's indices and scan a sorted copy for equal neighbors
    let mut row_cols: Vec<u32> = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let lineno = lineno + 1; // 1-based for error messages
        let line = line.map_err(|e| bad(lineno, format!("read: {e}")))?;
        // strip trailing comments ('#' starts a comment anywhere on the
        // line) and surrounding whitespace (including trailing '\r')
        let line = match line.split_once('#') {
            Some((head, _comment)) => head,
            None => line.as_str(),
        }
        .trim();
        if line.is_empty() {
            continue;
        }
        let row = labels.len();
        let mut parts = line.split_ascii_whitespace().peekable();
        let label_tok = parts.next().expect("non-empty trimmed line has a token");
        let label: f64 = label_tok
            .parse()
            .map_err(|_| bad(lineno, format!("bad label {label_tok:?}")))?;
        if !label.is_finite() {
            return Err(bad(lineno, format!("non-finite label {label_tok:?}")));
        }
        labels.push(label);
        // optional ranking qid field between the label and the features
        if let Some(tok) = parts.peek() {
            if let Some(q) = tok.strip_prefix("qid:") {
                q.parse::<u64>()
                    .map_err(|_| bad(lineno, format!("bad qid {q:?}")))?;
                parts.next();
            }
        }
        row_cols.clear();
        for tok in parts {
            let (idx, val) = tok
                .split_once(':')
                .ok_or_else(|| bad(lineno, format!("bad feature {tok:?} (want idx:val)")))?;
            let idx: usize = idx
                .parse()
                .map_err(|_| bad(lineno, format!("bad index {idx:?}")))?;
            if idx == 0 {
                return Err(bad(lineno, "libsvm indices are 1-based, found index 0"));
            }
            if idx > u32::MAX as usize {
                return Err(bad(lineno, format!("index {idx} exceeds u32 range")));
            }
            let val: f64 = val
                .parse()
                .map_err(|_| bad(lineno, format!("bad value {val:?}")))?;
            if !val.is_finite() {
                return Err(bad(lineno, format!("non-finite value {val:?} at index {idx}")));
            }
            max_col = max_col.max(idx);
            row_cols.push((idx - 1) as u32);
            triplets.push((row, (idx - 1) as u32, val));
        }
        // duplicate indices are ambiguous (last-wins? sum?) — reject them;
        // out-of-order indices are fine (the CSR builder sorts per row)
        row_cols.sort_unstable();
        if let Some(dup) = row_cols.windows(2).find(|p| p[0] == p[1]) {
            return Err(bad(lineno, format!("duplicate feature index {}", dup[0] + 1)));
        }
    }
    // normalize the {0,1} / {1,2} classification conventions to {-1,+1},
    // but only when the whole file looks like one — a single real-valued
    // response makes this a regression target set and binarizing it would
    // silently destroy the labels (see module docs)
    let classification = labels
        .iter()
        .all(|&y| y == -1.0 || y == 0.0 || y == 1.0 || y == 2.0);
    if classification {
        for y in labels.iter_mut() {
            *y = if *y <= 0.0 { -1.0 } else { 1.0 };
        }
    }
    let n = labels.len();
    let features = Features::Sparse(CsrMatrix::from_triplets(n, max_col, &triplets));
    Ok(Dataset::new(features, labels))
}

/// Write a dataset in LibSVM format (1-based indices, zeros skipped).
pub fn write_libsvm<P: AsRef<Path>>(ds: &Dataset, path: P) -> Result<()> {
    let file = File::create(&path)
        .with_context(|| format!("create {}", path.as_ref().display()))?;
    let mut w = BufWriter::new(file);
    for i in 0..ds.n() {
        write!(w, "{}", if ds.labels[i] > 0.0 { "+1" } else { "-1" })?;
        match &ds.features {
            Features::Sparse(m) => {
                let (indices, values) = m.row_view(i);
                for (idx, val) in indices.iter().zip(values) {
                    write!(w, " {}:{}", idx + 1, val)?;
                }
            }
            Features::Dense(m) => {
                for (j, &val) in m.row(i).iter().enumerate() {
                    if val != 0.0 {
                        write!(w, " {}:{}", j + 1, val)?;
                    }
                }
            }
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::cov_like;

    #[test]
    fn parse_basic() {
        let dir = std::env::temp_dir().join("cocoa_libsvm_parse");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("basic.svm");
        std::fs::write(&p, "+1 1:0.5 3:2.0\n-1 2:1.0\n# comment\n\n+1 3:0.1\n")
            .unwrap();
        let ds = read_libsvm(&p, 0).unwrap();
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.d(), 3);
        assert_eq!(ds.labels, vec![1.0, -1.0, 1.0]);
        assert_eq!(ds.features.row_dense(0), vec![0.5, 0.0, 2.0]);
    }

    #[test]
    fn label_conventions_normalized() {
        let dir = std::env::temp_dir().join("cocoa_libsvm_labels");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("labels.svm");
        std::fs::write(&p, "0 1:1\n2 1:1\n1 1:1\n").unwrap();
        let ds = read_libsvm(&p, 0).unwrap();
        assert_eq!(ds.labels, vec![-1.0, 1.0, 1.0]);
    }

    /// Write `content` to a scratch file and parse it.
    fn parse(tag: &str, content: &str) -> Result<crate::data::Dataset, Error> {
        let dir = std::env::temp_dir().join("cocoa_libsvm_hardening");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("{tag}.svm"));
        std::fs::write(&p, content).unwrap();
        read_libsvm(&p, 0)
    }

    #[test]
    fn rejects_zero_index() {
        let err = parse("zero", "+1 0:1.0\n").unwrap_err();
        assert!(matches!(err, Error::Libsvm { line: 1, .. }), "{err}");
        assert!(err.to_string().contains("1-based"), "{err}");
    }

    #[test]
    fn regression_targets_pass_through_unbinarized() {
        // one real-valued label anywhere => the whole file is regression
        let ds = parse("regression", "2.7 1:1.0\n-0.3 1:0.5\n1 2:1.0\n").unwrap();
        assert_eq!(ds.labels, vec![2.7, -0.3, 1.0]);
        // ...whereas an all-conventional file still normalizes
        let ds = parse("classif", "0 1:1.0\n2 1:0.5\n1 2:1.0\n-1 2:2.0\n").unwrap();
        assert_eq!(ds.labels, vec![-1.0, 1.0, 1.0, -1.0]);
    }

    #[test]
    fn accepts_qid_fields_and_ignores_them() {
        let ds = parse("qid", "+1 qid:3 1:0.5 2:1.0\n-1 qid:4 2:2.0\n").unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.d(), 2);
        assert_eq!(ds.features.row_dense(0), vec![0.5, 1.0]);
        assert_eq!(ds.features.row_dense(1), vec![0.0, 2.0]);
        // but a malformed qid is a typed error, not a feature
        let err = parse("badqid", "+1 qid:x 1:0.5\n").unwrap_err();
        assert!(matches!(err, Error::Libsvm { line: 1, .. }), "{err}");
    }

    #[test]
    fn accepts_inline_comments_and_trailing_whitespace() {
        let ds = parse(
            "comments",
            "# full-line comment\n+1 1:0.5 2:1.0 # trailing comment\n-1 1:2.0   \t\r\n",
        )
        .unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.features.row_dense(0), vec![0.5, 1.0]);
        assert_eq!(ds.features.row_dense(1), vec![2.0, 0.0]);
    }

    #[test]
    fn sorts_out_of_order_indices() {
        let ds = parse("ooo", "+1 3:3.0 1:1.0 2:2.0\n").unwrap();
        assert_eq!(ds.features.row_dense(0), vec![1.0, 2.0, 3.0]);
        // CSR invariant: indices strictly increasing within the row
        match &ds.features {
            crate::data::Features::Sparse(m) => {
                let idx = m.row_view(0).0;
                assert!(idx.windows(2).all(|p| p[0] < p[1]), "unsorted row: {idx:?}");
            }
            other => panic!("expected sparse features, got {other:?}"),
        }
    }

    #[test]
    fn rejects_duplicate_indices_with_line_number() {
        let err = parse("dup", "+1 1:1.0\n-1 2:1.0 3:0.5 2:2.0\n").unwrap_err();
        match err {
            Error::Libsvm { line, ref message } => {
                assert_eq!(line, 2);
                assert!(message.contains("duplicate feature index 2"), "{message}");
            }
            other => panic!("wrong variant: {other}"),
        }
    }

    #[test]
    fn rejects_malformed_tokens_with_typed_errors() {
        for (tag, text, needle) in [
            ("badlabel", "one 1:1.0\n", "label"),
            ("badindex", "+1 x:1.0\n", "index"),
            ("badvalue", "+1 1:abc\n", "value"),
            ("nocolon", "+1 1=1.0\n", "feature"),
            ("nonfinite", "+1 1:inf\n", "non-finite"),
            ("nanlabel", "nan 1:1.0\n", "label"),
            ("hugeindex", "+1 99999999999:1.0\n", "u32"),
        ] {
            let err = parse(tag, text).unwrap_err();
            assert!(
                matches!(err, Error::Libsvm { line: 1, .. }),
                "{tag}: wrong variant {err}"
            );
            assert!(err.to_string().contains(needle), "{tag}: {err}");
        }
    }

    #[test]
    fn missing_file_is_typed_not_a_panic() {
        let err = read_libsvm("/nonexistent/cocoa/no.svm", 0).unwrap_err();
        assert!(matches!(err, Error::Libsvm { line: 0, .. }), "{err}");
    }

    #[test]
    fn roundtrip_synthetic() {
        let ds = cov_like(30, 6, 0.1, 5);
        let dir = std::env::temp_dir().join("cocoa_libsvm_rt");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("rt.svm");
        write_libsvm(&ds, &p).unwrap();
        let back = read_libsvm(&p, ds.d()).unwrap();
        assert_eq!(back.n(), ds.n());
        assert_eq!(back.labels, ds.labels);
        for i in (0..ds.n()).step_by(7) {
            let a = ds.features.row_dense(i);
            let b = back.features.row_dense(i);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }
}
