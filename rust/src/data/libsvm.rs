//! LibSVM-format reader/writer.
//!
//! The paper's datasets (cov, rcv1, imagenet) ship in this format; with a
//! local copy, `[dataset] kind = "libsvm", path = "..."` in the experiment
//! config drops the real corpus into any harness. The writer exists so
//! synthetic datasets can be exported and round-tripped.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::{CsrMatrix, Dataset, Features};

/// Parse a LibSVM file: `label idx:val idx:val ...` per line, 1-based
/// indices. `d_hint` pre-sizes the column count (pass 0 to infer).
pub fn read_libsvm<P: AsRef<Path>>(path: P, d_hint: usize) -> Result<Dataset> {
    let file = File::open(&path)
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    let reader = BufReader::new(file);
    let mut labels = Vec::new();
    let mut triplets: Vec<(usize, u32, f64)> = Vec::new();
    let mut max_col: usize = d_hint;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let row = labels.len();
        let mut parts = line.split_ascii_whitespace();
        let label_tok = parts
            .next()
            .ok_or_else(|| anyhow!("line {}: empty record", lineno + 1))?;
        let label: f64 = label_tok
            .parse()
            .with_context(|| format!("line {}: bad label {label_tok:?}", lineno + 1))?;
        // normalize {0,1} and {1,2} label conventions to {-1,+1}
        let label = if label <= 0.0 { -1.0 } else { 1.0 };
        labels.push(label);
        for tok in parts {
            let (idx, val) = tok
                .split_once(':')
                .ok_or_else(|| anyhow!("line {}: bad feature {tok:?}", lineno + 1))?;
            let idx: usize = idx
                .parse()
                .with_context(|| format!("line {}: bad index {idx:?}", lineno + 1))?;
            if idx == 0 {
                return Err(anyhow!("line {}: libsvm indices are 1-based", lineno + 1));
            }
            let val: f64 = val
                .parse()
                .with_context(|| format!("line {}: bad value {val:?}", lineno + 1))?;
            max_col = max_col.max(idx);
            triplets.push((row, (idx - 1) as u32, val));
        }
    }
    let n = labels.len();
    let features = Features::Sparse(CsrMatrix::from_triplets(n, max_col, &triplets));
    Ok(Dataset::new(features, labels))
}

/// Write a dataset in LibSVM format (1-based indices, zeros skipped).
pub fn write_libsvm<P: AsRef<Path>>(ds: &Dataset, path: P) -> Result<()> {
    let file = File::create(&path)
        .with_context(|| format!("create {}", path.as_ref().display()))?;
    let mut w = BufWriter::new(file);
    for i in 0..ds.n() {
        write!(w, "{}", if ds.labels[i] > 0.0 { "+1" } else { "-1" })?;
        match &ds.features {
            Features::Sparse(m) => {
                let r = m.row_range(i);
                for (idx, val) in m.indices[r.clone()].iter().zip(&m.values[r]) {
                    write!(w, " {}:{}", idx + 1, val)?;
                }
            }
            Features::Dense(m) => {
                for (j, &val) in m.row(i).iter().enumerate() {
                    if val != 0.0 {
                        write!(w, " {}:{}", j + 1, val)?;
                    }
                }
            }
        }
        writeln!(w)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::cov_like;

    #[test]
    fn parse_basic() {
        let dir = std::env::temp_dir().join("cocoa_libsvm_parse");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("basic.svm");
        std::fs::write(&p, "+1 1:0.5 3:2.0\n-1 2:1.0\n# comment\n\n+1 3:0.1\n")
            .unwrap();
        let ds = read_libsvm(&p, 0).unwrap();
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.d(), 3);
        assert_eq!(ds.labels, vec![1.0, -1.0, 1.0]);
        assert_eq!(ds.features.row_dense(0), vec![0.5, 0.0, 2.0]);
    }

    #[test]
    fn label_conventions_normalized() {
        let dir = std::env::temp_dir().join("cocoa_libsvm_labels");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("labels.svm");
        std::fs::write(&p, "0 1:1\n2 1:1\n1 1:1\n").unwrap();
        let ds = read_libsvm(&p, 0).unwrap();
        assert_eq!(ds.labels, vec![-1.0, 1.0, 1.0]);
    }

    #[test]
    fn rejects_zero_index() {
        let dir = std::env::temp_dir().join("cocoa_libsvm_zero");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("zero.svm");
        std::fs::write(&p, "+1 0:1.0\n").unwrap();
        assert!(read_libsvm(&p, 0).is_err());
    }

    #[test]
    fn roundtrip_synthetic() {
        let ds = cov_like(30, 6, 0.1, 5);
        let dir = std::env::temp_dir().join("cocoa_libsvm_rt");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("rt.svm");
        write_libsvm(&ds, &p).unwrap();
        let back = read_libsvm(&p, ds.d()).unwrap();
        assert_eq!(back.n(), ds.n());
        assert_eq!(back.labels, ds.labels);
        for i in (0..ds.n()).step_by(7) {
            let a = ds.features.row_dense(i);
            let b = back.features.row_dense(i);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }
}
