//! LibSVM-format reader, writer, and streaming sharder.
//!
//! The paper's datasets (cov, rcv1, imagenet) ship in this format; with a
//! local copy, `[dataset] kind = "libsvm", path = "..."` in the experiment
//! config drops the real corpus into any harness. The writer exists so
//! synthetic datasets can be exported and round-tripped. For corpora that
//! do not fit in memory, [`shard_libsvm`] streams the file once and writes
//! per-worker on-disk shards directly (see [`crate::data::mmap`] and
//! `docs/DATA.md`).
//!
//! Both ingesters accept gzip-compressed files transparently: a `.gz`
//! extension (any case) routes the open through the built-in inflater
//! (see [`super::gzip`]), so `rcv1.svm.gz` works wherever `rcv1.svm`
//! does and parses to the identical dataset.
//!
//! The reader is hardened against the format's wild variants: `qid:` rank
//! fields and comments (full-line and trailing `# ...`) are accepted,
//! out-of-order feature indices are sorted, and every malformed input —
//! bad labels/indices/values, duplicate indices, 0-based indices,
//! non-finite values — surfaces as the typed
//! [`Error::Libsvm`](crate::error::Error::Libsvm) with a 1-based line
//! number instead of a panic or a stringly error.
//!
//! Labels: when *every* label is one of the classification conventions
//! `{-1, 0, 1, 2}` the file is treated as binary and normalized to
//! `{-1, +1}` (`<= 0` maps to `-1`); any other value anywhere makes the
//! whole file a regression target set and labels pass through untouched —
//! lasso/squared-loss workloads keep their real-valued responses.

use std::fs::File;
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::error::Error;
use crate::kernels;

use super::mmap::{ShardSet, ShardSetWriter};
use super::{CsrMatrix, Dataset, Features, PartitionStrategy};

fn bad(line: usize, message: impl Into<String>) -> Error {
    Error::Libsvm { line, message: message.into() }
}

/// Strip the trailing comment (`#` starts one anywhere) and surrounding
/// whitespace; `None` when nothing remains. Both the whole-file reader
/// and the streaming sharder (including its row-counting pre-pass) agree
/// on this single definition of "a data line".
fn data_line(raw: &str) -> Option<&str> {
    let line = match raw.split_once('#') {
        Some((head, _comment)) => head,
        None => raw,
    }
    .trim();
    if line.is_empty() {
        None
    } else {
        Some(line)
    }
}

/// Parse one non-empty data line: `label [qid:<q>] idx:val ...` with
/// 1-based indices. Fills `entries` with 0-based `(col, value)` pairs in
/// file order and returns the label; `scratch` is a reusable buffer for
/// the sorted-copy duplicate scan. Every malformed token is the typed
/// [`Error::Libsvm`](crate::error::Error::Libsvm) carrying `lineno`.
fn parse_data_line(
    lineno: usize,
    line: &str,
    entries: &mut Vec<(u32, f64)>,
    scratch: &mut Vec<u32>,
) -> Result<f64, Error> {
    entries.clear();
    let mut parts = line.split_ascii_whitespace().peekable();
    let label_tok = parts.next().expect("non-empty trimmed line has a token");
    let label: f64 = label_tok
        .parse()
        .map_err(|_| bad(lineno, format!("bad label {label_tok:?}")))?;
    if !label.is_finite() {
        return Err(bad(lineno, format!("non-finite label {label_tok:?}")));
    }
    // optional ranking qid field between the label and the features
    if let Some(tok) = parts.peek() {
        if let Some(q) = tok.strip_prefix("qid:") {
            q.parse::<u64>().map_err(|_| bad(lineno, format!("bad qid {q:?}")))?;
            parts.next();
        }
    }
    for tok in parts {
        let (idx, val) = tok
            .split_once(':')
            .ok_or_else(|| bad(lineno, format!("bad feature {tok:?} (want idx:val)")))?;
        let idx: usize = idx
            .parse()
            .map_err(|_| bad(lineno, format!("bad index {idx:?}")))?;
        if idx == 0 {
            return Err(bad(lineno, "libsvm indices are 1-based, found index 0"));
        }
        if idx > u32::MAX as usize {
            return Err(bad(lineno, format!("index {idx} exceeds u32 range")));
        }
        let val: f64 = val
            .parse()
            .map_err(|_| bad(lineno, format!("bad value {val:?}")))?;
        if !val.is_finite() {
            return Err(bad(lineno, format!("non-finite value {val:?} at index {idx}")));
        }
        entries.push(((idx - 1) as u32, val));
    }
    // duplicate indices are ambiguous (last-wins? sum?) — reject them;
    // out-of-order indices are fine (callers sort per row)
    scratch.clear();
    scratch.extend(entries.iter().map(|&(c, _)| c));
    scratch.sort_unstable();
    if let Some(dup) = scratch.windows(2).find(|p| p[0] == p[1]) {
        return Err(bad(lineno, format!("duplicate feature index {}", dup[0] + 1)));
    }
    Ok(label)
}

/// The whole-file classification convention: only when *every* label is
/// in `{-1, 0, 1, 2}` is the file binary (see module docs).
fn is_classification_label(y: f64) -> bool {
    y == -1.0 || y == 0.0 || y == 1.0 || y == 2.0
}

/// Parse a LibSVM file: `label [qid:<q>] idx:val idx:val ... [# comment]`
/// per line, 1-based indices. `d_hint` pre-sizes the column count (pass 0
/// to infer). Malformed input yields the typed
/// [`Error::Libsvm`](crate::error::Error::Libsvm) — see the module docs
/// for exactly what is accepted.
pub fn read_libsvm<P: AsRef<Path>>(path: P, d_hint: usize) -> Result<Dataset, Error> {
    let reader = super::gzip::open_maybe_gz(path.as_ref())
        .map_err(|e| bad(0, format!("open {}: {e}", path.as_ref().display())))?;
    let mut labels = Vec::new();
    let mut triplets: Vec<(usize, u32, f64)> = Vec::new();
    let mut max_col: usize = d_hint;
    // reusable per-row buffers (duplicate detection scans a sorted copy
    // rather than a hash set — offline build)
    let mut entries: Vec<(u32, f64)> = Vec::new();
    let mut scratch: Vec<u32> = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let lineno = lineno + 1; // 1-based for error messages
        let line = line.map_err(|e| bad(lineno, format!("read: {e}")))?;
        let Some(line) = data_line(&line) else { continue };
        let row = labels.len();
        let label = parse_data_line(lineno, line, &mut entries, &mut scratch)?;
        labels.push(label);
        for &(c, v) in &entries {
            max_col = max_col.max(c as usize + 1);
            triplets.push((row, c, v));
        }
    }
    // normalize the {0,1} / {1,2} classification conventions to {-1,+1},
    // but only when the whole file looks like one — a single real-valued
    // response makes this a regression target set and binarizing it would
    // silently destroy the labels (see module docs)
    let classification = labels.iter().all(|&y| is_classification_label(y));
    if classification {
        for y in labels.iter_mut() {
            *y = if *y <= 0.0 { -1.0 } else { 1.0 };
        }
    }
    let n = labels.len();
    let features = Features::Sparse(CsrMatrix::from_triplets(n, max_col, &triplets));
    Ok(Dataset::new(features, labels))
}

/// Write a dataset in LibSVM format (1-based indices, zeros skipped).
pub fn write_libsvm<P: AsRef<Path>>(ds: &Dataset, path: P) -> Result<()> {
    let file = File::create(&path)
        .with_context(|| format!("create {}", path.as_ref().display()))?;
    let mut w = BufWriter::new(file);
    for i in 0..ds.n() {
        write!(w, "{}", if ds.labels[i] > 0.0 { "+1" } else { "-1" })?;
        match &ds.features {
            Features::Sparse(m) => {
                let (indices, values) = m.row_view(i);
                for (idx, val) in indices.iter().zip(values) {
                    write!(w, " {}:{}", idx + 1, val)?;
                }
            }
            Features::Dense(m) => {
                for (j, &val) in m.row(i).iter().enumerate() {
                    if val != 0.0 {
                        write!(w, " {}:{}", j + 1, val)?;
                    }
                }
            }
        }
        writeln!(w)?;
    }
    Ok(())
}

/// Stream a LibSVM file straight into per-worker on-disk shards (the
/// `cocoa shard --libsvm` ingest path) without ever materializing the
/// full dataset: each parsed row goes to its partition block's shard
/// file as it streams by, so peak memory is O(n) scalars — labels, row
/// norms, per-shard `indptr` — never O(nnz).
///
/// The result is byte-for-byte what `read_libsvm` + `write_shards` would
/// produce: the same hardened per-line parser, the same whole-file
/// classification binarization, and (with `normalize`) the same
/// `Dataset::normalize_rows` arithmetic, applied per row in stream order.
/// A shard opened from the output is therefore bit-identical to
/// `read_libsvm(path)?.subset(&partition.blocks[k])`.
///
/// `strategy` follows [`PartitionStrategy`]: `round_robin` is truly
/// single-pass; `contiguous` and `random` need the row count up front and
/// cost one extra cheap line-counting pass over the file (for a `.gz`
/// input each pass decompresses afresh). `d_hint` pre-sizes the column
/// count exactly as in [`read_libsvm`] (pass 0 to infer).
///
/// ```
/// use cocoa::data::{read_libsvm, shard_libsvm, PartitionStrategy};
///
/// let dir = std::env::temp_dir().join("cocoa_doc_shard_libsvm");
/// let _ = std::fs::remove_dir_all(&dir);
/// std::fs::create_dir_all(&dir).unwrap();
/// let svm = dir.join("tiny.svm");
/// std::fs::write(&svm, "+1 1:0.5 3:2.0\n-1 2:1.0\n+1 3:0.1\n-1 1:0.2\n").unwrap();
///
/// let set = shard_libsvm(&svm, dir.join("shards"), 2,
///                        PartitionStrategy::RoundRobin, 0, 0, false).unwrap();
/// assert_eq!((set.n(), set.d(), set.k()), (4, 3, 2));
/// // shard 0 holds global rows {0, 2}, exactly as the in-memory path would
/// let full = read_libsvm(&svm, 0).unwrap();
/// assert_eq!(set.open_shard(0).unwrap().labels,
///            full.subset(&set.partition().blocks[0]).labels);
/// ```
pub fn shard_libsvm<P: AsRef<Path>, Q: AsRef<Path>>(
    path: P,
    dir: Q,
    k: usize,
    strategy: PartitionStrategy,
    partition_seed: u64,
    d_hint: usize,
    normalize: bool,
) -> Result<ShardSet, Error> {
    let path = path.as_ref();
    let open = || -> Result<Box<dyn BufRead>, Error> {
        super::gzip::open_maybe_gz(path).map_err(|e| bad(0, format!("open {}: {e}", path.display())))
    };
    // contiguous/random block boundaries depend on n, so those strategies
    // pay a cheap counting pre-pass; round_robin streams in one pass
    let n = match strategy {
        PartitionStrategy::RoundRobin => None,
        _ => {
            let mut count = 0usize;
            for (lineno, line) in open()?.lines().enumerate() {
                let line = line.map_err(|e| bad(lineno + 1, format!("read: {e}")))?;
                if data_line(&line).is_some() {
                    count += 1;
                }
            }
            Some(count)
        }
    };
    let mut writer = ShardSetWriter::create(dir, k, strategy, partition_seed, n)?;
    let mut max_col: usize = d_hint;
    let mut entries: Vec<(u32, f64)> = Vec::new();
    let mut scratch: Vec<u32> = Vec::new();
    let mut idx_buf: Vec<u32> = Vec::new();
    let mut val_buf: Vec<f64> = Vec::new();
    let mut classification = true;
    for (lineno, line) in open()?.lines().enumerate() {
        let lineno = lineno + 1;
        let line = line.map_err(|e| bad(lineno, format!("read: {e}")))?;
        let Some(line) = data_line(&line) else { continue };
        let label = parse_data_line(lineno, line, &mut entries, &mut scratch)?;
        classification &= is_classification_label(label);
        // sort by column first: norms are summed over the sorted row,
        // matching the bits the in-memory path (from_triplets then
        // Dataset::new) produces
        entries.sort_unstable_by_key(|&(c, _)| c);
        idx_buf.clear();
        val_buf.clear();
        for &(c, v) in &entries {
            max_col = max_col.max(c as usize + 1);
            idx_buf.push(c);
            val_buf.push(v);
        }
        let mut norm_sq = kernels::sparse_norm_sq(&val_buf);
        if normalize {
            // exactly Dataset::normalize_rows: rows inside the unit ball
            // are untouched, scaled rows cache a norm of exactly 1.0
            let norm = norm_sq.sqrt();
            if norm > 1.0 {
                kernels::scale_in_place(&mut val_buf, 1.0 / norm);
                norm_sq = 1.0;
            }
        }
        writer.push_row(&idx_buf, &val_buf, label, norm_sq)?;
    }
    if classification {
        writer.map_labels(|y| if y <= 0.0 { -1.0 } else { 1.0 });
    }
    writer.finish(max_col)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::cov_like;

    #[test]
    fn parse_basic() {
        let dir = std::env::temp_dir().join("cocoa_libsvm_parse");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("basic.svm");
        std::fs::write(&p, "+1 1:0.5 3:2.0\n-1 2:1.0\n# comment\n\n+1 3:0.1\n")
            .unwrap();
        let ds = read_libsvm(&p, 0).unwrap();
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.d(), 3);
        assert_eq!(ds.labels, vec![1.0, -1.0, 1.0]);
        assert_eq!(ds.features.row_dense(0), vec![0.5, 0.0, 2.0]);
    }

    #[test]
    fn label_conventions_normalized() {
        let dir = std::env::temp_dir().join("cocoa_libsvm_labels");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("labels.svm");
        std::fs::write(&p, "0 1:1\n2 1:1\n1 1:1\n").unwrap();
        let ds = read_libsvm(&p, 0).unwrap();
        assert_eq!(ds.labels, vec![-1.0, 1.0, 1.0]);
    }

    /// Write `content` to a scratch file and parse it.
    fn parse(tag: &str, content: &str) -> Result<crate::data::Dataset, Error> {
        let dir = std::env::temp_dir().join("cocoa_libsvm_hardening");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("{tag}.svm"));
        std::fs::write(&p, content).unwrap();
        read_libsvm(&p, 0)
    }

    #[test]
    fn rejects_zero_index() {
        let err = parse("zero", "+1 0:1.0\n").unwrap_err();
        assert!(matches!(err, Error::Libsvm { line: 1, .. }), "{err}");
        assert!(err.to_string().contains("1-based"), "{err}");
    }

    #[test]
    fn regression_targets_pass_through_unbinarized() {
        // one real-valued label anywhere => the whole file is regression
        let ds = parse("regression", "2.7 1:1.0\n-0.3 1:0.5\n1 2:1.0\n").unwrap();
        assert_eq!(ds.labels, vec![2.7, -0.3, 1.0]);
        // ...whereas an all-conventional file still normalizes
        let ds = parse("classif", "0 1:1.0\n2 1:0.5\n1 2:1.0\n-1 2:2.0\n").unwrap();
        assert_eq!(ds.labels, vec![-1.0, 1.0, 1.0, -1.0]);
    }

    #[test]
    fn accepts_qid_fields_and_ignores_them() {
        let ds = parse("qid", "+1 qid:3 1:0.5 2:1.0\n-1 qid:4 2:2.0\n").unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.d(), 2);
        assert_eq!(ds.features.row_dense(0), vec![0.5, 1.0]);
        assert_eq!(ds.features.row_dense(1), vec![0.0, 2.0]);
        // but a malformed qid is a typed error, not a feature
        let err = parse("badqid", "+1 qid:x 1:0.5\n").unwrap_err();
        assert!(matches!(err, Error::Libsvm { line: 1, .. }), "{err}");
    }

    #[test]
    fn accepts_inline_comments_and_trailing_whitespace() {
        let ds = parse(
            "comments",
            "# full-line comment\n+1 1:0.5 2:1.0 # trailing comment\n-1 1:2.0   \t\r\n",
        )
        .unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.features.row_dense(0), vec![0.5, 1.0]);
        assert_eq!(ds.features.row_dense(1), vec![2.0, 0.0]);
    }

    #[test]
    fn sorts_out_of_order_indices() {
        let ds = parse("ooo", "+1 3:3.0 1:1.0 2:2.0\n").unwrap();
        assert_eq!(ds.features.row_dense(0), vec![1.0, 2.0, 3.0]);
        // CSR invariant: indices strictly increasing within the row
        match &ds.features {
            crate::data::Features::Sparse(m) => {
                let idx = m.row_view(0).0;
                assert!(idx.windows(2).all(|p| p[0] < p[1]), "unsorted row: {idx:?}");
            }
            other => panic!("expected sparse features, got {other:?}"),
        }
    }

    #[test]
    fn rejects_duplicate_indices_with_line_number() {
        let err = parse("dup", "+1 1:1.0\n-1 2:1.0 3:0.5 2:2.0\n").unwrap_err();
        match err {
            Error::Libsvm { line, ref message } => {
                assert_eq!(line, 2);
                assert!(message.contains("duplicate feature index 2"), "{message}");
            }
            other => panic!("wrong variant: {other}"),
        }
    }

    #[test]
    fn rejects_malformed_tokens_with_typed_errors() {
        for (tag, text, needle) in [
            ("badlabel", "one 1:1.0\n", "label"),
            ("badindex", "+1 x:1.0\n", "index"),
            ("badvalue", "+1 1:abc\n", "value"),
            ("nocolon", "+1 1=1.0\n", "feature"),
            ("nonfinite", "+1 1:inf\n", "non-finite"),
            ("nanlabel", "nan 1:1.0\n", "label"),
            ("hugeindex", "+1 99999999999:1.0\n", "u32"),
        ] {
            let err = parse(tag, text).unwrap_err();
            assert!(
                matches!(err, Error::Libsvm { line: 1, .. }),
                "{tag}: wrong variant {err}"
            );
            assert!(err.to_string().contains(needle), "{tag}: {err}");
        }
    }

    #[test]
    fn missing_file_is_typed_not_a_panic() {
        let err = read_libsvm("/nonexistent/cocoa/no.svm", 0).unwrap_err();
        assert!(matches!(err, Error::Libsvm { line: 0, .. }), "{err}");
    }

    #[test]
    fn stream_sharding_matches_in_memory_partition_bitwise() {
        // the ingester property: for every strategy, shard k of the
        // streamed file == read_libsvm(file).subset(blocks[k]), bit for bit
        let ds = crate::data::rcv1_like(60, 25, 4, 0.1, 17);
        let dir = std::env::temp_dir().join("cocoa_libsvm_shard_prop");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("prop.svm");
        write_libsvm(&ds, &p).unwrap();
        let full = read_libsvm(&p, 0).unwrap();
        for strategy in [
            PartitionStrategy::Contiguous,
            PartitionStrategy::RoundRobin,
            PartitionStrategy::Random,
        ] {
            let out = dir.join(format!("shards_{}", strategy.name()));
            let set = shard_libsvm(&p, &out, 3, strategy, 7, 0, false).unwrap();
            assert_eq!(set.fingerprint(), full.fingerprint(), "{strategy:?}");
            let partition = set.partition();
            for kid in 0..3 {
                let shard = set.open_shard(kid).unwrap();
                let reference = full.subset(&partition.blocks[kid]);
                assert_eq!(shard.labels, reference.labels, "{strategy:?} shard {kid}");
                for i in 0..shard.n() {
                    assert_eq!(
                        shard.norm_sq(i).to_bits(),
                        reference.norm_sq(i).to_bits(),
                        "{strategy:?} shard {kid} row {i}"
                    );
                    assert_eq!(
                        shard.features.row_dense(i),
                        reference.features.row_dense(i),
                        "{strategy:?} shard {kid} row {i}"
                    );
                }
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn stream_sharding_normalize_matches_normalize_rows() {
        let ds = crate::data::rcv1_like(40, 20, 4, 0.1, 23);
        let dir = std::env::temp_dir().join("cocoa_libsvm_shard_norm");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("norm.svm");
        write_libsvm(&ds, &p).unwrap();
        let mut full = read_libsvm(&p, 0).unwrap();
        full.normalize_rows();
        let set =
            shard_libsvm(&p, dir.join("shards"), 2, PartitionStrategy::Contiguous, 0, 0, true)
                .unwrap();
        assert_eq!(set.fingerprint(), full.fingerprint());
        let partition = set.partition();
        for kid in 0..2 {
            let shard = set.open_shard(kid).unwrap();
            let reference = full.subset(&partition.blocks[kid]);
            for i in 0..shard.n() {
                assert_eq!(
                    shard.features.row_dense(i),
                    reference.features.row_dense(i),
                    "shard {kid} row {i}"
                );
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn gz_twin_parses_and_shards_identically() {
        // a .gz file and its uncompressed twin must be indistinguishable
        // to both ingesters, bit for bit
        let ds = crate::data::rcv1_like(50, 20, 4, 0.1, 31);
        let dir = std::env::temp_dir().join("cocoa_libsvm_gz");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let plain = dir.join("twin.svm");
        write_libsvm(&ds, &plain).unwrap();
        let text = std::fs::read(&plain).unwrap();
        for (name, gz_bytes) in [
            ("stored.svm.gz", crate::data::gzip::testgz::gzip_stored(&text)),
            ("dynamic.svm.gz", crate::data::gzip::testgz::gzip_dynamic(&text)),
        ] {
            let gz = dir.join(name);
            std::fs::write(&gz, gz_bytes).unwrap();
            let a = read_libsvm(&plain, 0).unwrap();
            let b = read_libsvm(&gz, 0).unwrap();
            assert_eq!(a.fingerprint(), b.fingerprint(), "{name}");
            assert_eq!(a.labels, b.labels, "{name}");
            // contiguous exercises the counting pre-pass on the gz stream
            let sp = dir.join(format!("{name}.shards_plain"));
            let sg = dir.join(format!("{name}.shards_gz"));
            let set_a =
                shard_libsvm(&plain, &sp, 2, PartitionStrategy::Contiguous, 0, 0, false).unwrap();
            let set_b =
                shard_libsvm(&gz, &sg, 2, PartitionStrategy::Contiguous, 0, 0, false).unwrap();
            assert_eq!(set_a.fingerprint(), set_b.fingerprint(), "{name}");
            for kid in 0..2 {
                let x = set_a.open_shard(kid).unwrap();
                let y = set_b.open_shard(kid).unwrap();
                assert_eq!(x.labels, y.labels, "{name} shard {kid}");
                for i in 0..x.n() {
                    assert_eq!(x.features.row_dense(i), y.features.row_dense(i));
                }
            }
        }
        // corrupt gz input is a typed Libsvm error, not a panic
        let gz = dir.join("bad.svm.gz");
        std::fs::write(&gz, b"\x1f\x8bnot really gzip").unwrap();
        let err = read_libsvm(&gz, 0).unwrap_err();
        assert!(matches!(err, Error::Libsvm { line: 0, .. }), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn roundtrip_synthetic() {
        let ds = cov_like(30, 6, 0.1, 5);
        let dir = std::env::temp_dir().join("cocoa_libsvm_rt");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("rt.svm");
        write_libsvm(&ds, &p).unwrap();
        let back = read_libsvm(&p, ds.d()).unwrap();
        assert_eq!(back.n(), ds.n());
        assert_eq!(back.labels, ds.labels);
        for i in (0..ds.n()).step_by(7) {
            let a = ds.features.row_dense(i);
            let b = back.features.row_dense(i);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }
}
