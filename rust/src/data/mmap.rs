//! Out-of-core shard storage: the versioned on-disk CSR format, a
//! streaming shard-set writer, and the mmap-backed read path.
//!
//! A *shard set* is a directory holding one `shard_NNNN.bin` per worker
//! plus a `manifest.toml` describing the global dataset (n, d, nnz, the
//! partition that produced the shards, and the full-dataset fingerprint
//! used by the net handshake). Shard `k` contains exactly the rows of
//! partition block `k`, in ascending global-row order — the same rows,
//! in the same order, that the in-memory path's `Dataset::subset` would
//! hand worker `k`. Labels and cached row norms are stored alongside the
//! CSR sections, so opening a shard never recomputes (and therefore never
//! pages through) anything: the trajectory from shards is bit-identical
//! to the in-memory trajectory by construction.
//!
//! Every section is FNV-1a checksummed and the open path verifies the
//! checksums *and* the CSR invariants (per-row indices strictly
//! increasing, every `index < cols`, `indptr` monotone with
//! `indptr[rows] == nnz`, all floats finite) with buffered streaming
//! reads before any data is trusted. That verification is what keeps the
//! unchecked gather kernels sound on mapped data — see `docs/DATA.md`
//! for the full contract. Corruption is rejected with the typed
//! [`Error::Shard`].
//!
//! On 64-bit linux/macOS the index/value sections are `mmap`ed
//! (read-only, `MAP_PRIVATE`) and only faulted in as rows are touched; a
//! residency budget periodically drops clean pages with
//! `madvise(MADV_DONTNEED)` so peak RSS stays bounded far below the
//! dataset size. Elsewhere — or with [`ShardMode::Owned`] — the sections
//! are simply read into memory, same bytes, same trajectory.
//!
//! ```
//! use cocoa::data::{rcv1_like, write_shards, PartitionStrategy, ShardSet};
//!
//! let data = rcv1_like(60, 40, 4, 0.1, 7);
//! let dir = std::env::temp_dir().join("cocoa_doc_shards");
//! let _ = std::fs::remove_dir_all(&dir);
//! let set = write_shards(&data, PartitionStrategy::Contiguous, 2, 0, &dir).unwrap();
//! assert_eq!((set.n(), set.d(), set.k()), (60, 40, 2));
//! let shard0 = set.open_shard(0).unwrap();
//! assert_eq!(shard0.n(), 30);
//! assert_eq!(set.fingerprint(), data.fingerprint());
//! ```

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::kernels;
use crate::util::toml_lite::Doc;

use super::{
    fingerprint_parts, CsrMatrix, Dataset, Features, Partition, PartitionStrategy,
};

/// First 8 bytes of every shard file.
pub const SHARD_MAGIC: &[u8; 8] = b"CCOASHRD";
/// On-disk format version; the open path rejects any other value.
pub const SHARD_FORMAT_VERSION: u32 = 1;
/// Manifest format version (the `manifest.toml` layout).
pub const MANIFEST_VERSION: u32 = 1;

/// Fixed header size: magic + version + shape + 5-entry section table +
/// header checksum, padded to an 8-byte boundary.
const HEADER_BYTES: usize = 192;
/// Section order inside a shard file.
const SEC_INDPTR: usize = 0;
const SEC_INDICES: usize = 1;
const SEC_VALUES: usize = 2;
const SEC_LABELS: usize = 3;
const SEC_NORMS: usize = 4;
const SECTIONS: usize = 5;

/// Touched-bytes budget before the mapped sections are dropped back to
/// the page cache with `madvise(MADV_DONTNEED)`. Clean read-only
/// file-backed pages refault to identical bytes, so this bounds resident
/// memory without affecting the trajectory.
pub(crate) const RESIDENCY_BUDGET_BYTES: usize = 16 << 20;

fn shard_err(path: &Path, message: impl Into<String>) -> Error {
    Error::Shard { path: path.display().to_string(), message: message.into() }
}

// ---------------------------------------------------------------------------
// FNV-1a over byte streams (the section checksum)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf29ce484222325)
    }

    fn update(&mut self, bytes: &[u8]) {
        let mut h = self.0;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        self.0 = h;
    }

    fn finish(self) -> u64 {
        self.0
    }
}

// ---------------------------------------------------------------------------
// mmap FFI — same direct-binding pattern as telemetry::thread_cpu_time_s
// (the offline build carries no libc crate). Gated to 64-bit unix targets
// we actually run on; everywhere else ShardMode::Mapped falls back to an
// owned in-memory load of the same verified bytes.
// ---------------------------------------------------------------------------

#[cfg(all(
    unix,
    target_pointer_width = "64",
    any(target_os = "linux", target_os = "macos")
))]
mod sys {
    use std::os::unix::io::AsRawFd;

    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;
    pub const MADV_DONTNEED: i32 = 4;

    extern "C" {
        fn mmap(
            addr: *mut u8,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut u8;
        fn munmap(addr: *mut u8, len: usize) -> i32;
        fn madvise(addr: *mut u8, len: usize, advice: i32) -> i32;
    }

    pub fn map_file(file: &std::fs::File, len: usize) -> Option<*mut u8> {
        if len == 0 {
            return None;
        }
        // SAFETY: a fresh read-only private mapping of `len` bytes backed
        // by an open fd; the kernel validates every argument.
        let ptr = unsafe {
            mmap(
                std::ptr::null_mut(),
                len,
                PROT_READ,
                MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 || ptr.is_null() {
            None
        } else {
            Some(ptr)
        }
    }

    /// # Safety
    /// `ptr`/`len` must be a live mapping returned by [`map_file`].
    pub unsafe fn unmap(ptr: *mut u8, len: usize) {
        munmap(ptr, len);
    }

    /// Best-effort `madvise(MADV_DONTNEED)` over the 64 KiB-aligned
    /// interior of `[ptr, ptr+len)` — 64 KiB alignment is a multiple of
    /// every page size we run on, so the call never straddles a partial
    /// page. Failure is ignored: DONTNEED on a clean private file
    /// mapping is purely an RSS hint.
    ///
    /// # Safety
    /// `ptr`/`len` must be a live mapping returned by [`map_file`].
    pub unsafe fn drop_resident(ptr: *mut u8, len: usize) {
        const ALIGN: usize = 64 << 10;
        let start = ptr as usize;
        let lo = (start + ALIGN - 1) & !(ALIGN - 1);
        let hi = (start + len) & !(ALIGN - 1);
        if hi > lo {
            madvise(lo as *mut u8, hi - lo, MADV_DONTNEED);
        }
    }

    pub const SUPPORTED: bool = true;
}

#[cfg(not(all(
    unix,
    target_pointer_width = "64",
    any(target_os = "linux", target_os = "macos")
)))]
mod sys {
    pub fn map_file(_file: &std::fs::File, _len: usize) -> Option<*mut u8> {
        None
    }

    /// # Safety
    /// Never called: `map_file` never returns a pointer on this target.
    pub unsafe fn unmap(_ptr: *mut u8, _len: usize) {}

    /// # Safety
    /// Never called: `map_file` never returns a pointer on this target.
    pub unsafe fn drop_resident(_ptr: *mut u8, _len: usize) {}

    pub const SUPPORTED: bool = false;
}

/// Whether this build can actually `mmap` shard files. When `false`,
/// [`ShardMode::Mapped`] silently degrades to an owned in-memory load of
/// the same verified bytes (same trajectory, no RSS bound).
pub fn mmap_supported() -> bool {
    sys::SUPPORTED
}

/// One live read-only file mapping; unmapped on drop.
struct MapRegion {
    ptr: *mut u8,
    len: usize,
}

// SAFETY: the mapping is read-only for its whole lifetime; concurrent
// reads from worker threads are races only with `madvise(DONTNEED)`,
// which atomically replaces clean pages with identical refaulted bytes.
unsafe impl Send for MapRegion {}
unsafe impl Sync for MapRegion {}

impl Drop for MapRegion {
    fn drop(&mut self) {
        // SAFETY: ptr/len came from sys::map_file and are unmapped once.
        unsafe { sys::unmap(self.ptr, self.len) };
    }
}

impl std::fmt::Debug for MapRegion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "MapRegion({} bytes)", self.len)
    }
}

/// The mapped index/value sections of one shard, handed to
/// [`CsrMatrix`] as its `Storage::Mapped` backing. Cloning shares the
/// mapping (`Arc`) but gives the clone a fresh residency counter.
pub(crate) struct MappedCsr {
    region: Arc<MapRegion>,
    idx_off: usize,
    idx_len: usize,
    val_off: usize,
    val_len: usize,
    touched: AtomicUsize,
}

impl std::fmt::Debug for MappedCsr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "MappedCsr(nnz = {})", self.idx_len)
    }
}

impl Clone for MappedCsr {
    fn clone(&self) -> Self {
        MappedCsr {
            region: Arc::clone(&self.region),
            idx_off: self.idx_off,
            idx_len: self.idx_len,
            val_off: self.val_off,
            val_len: self.val_len,
            touched: AtomicUsize::new(0),
        }
    }
}

impl MappedCsr {
    /// The full indices section. Raw view — no residency accounting.
    #[inline]
    pub(crate) fn indices(&self) -> &[u32] {
        // SAFETY: the open path validated that the section lies inside
        // the mapping at an 8-aligned offset; the mapping outlives self.
        unsafe {
            std::slice::from_raw_parts(
                self.region.ptr.add(self.idx_off) as *const u32,
                self.idx_len,
            )
        }
    }

    /// The full values section. Raw view — no residency accounting.
    #[inline]
    pub(crate) fn values(&self) -> &[f64] {
        // SAFETY: as in `indices`.
        unsafe {
            std::slice::from_raw_parts(
                self.region.ptr.add(self.val_off) as *const f64,
                self.val_len,
            )
        }
    }

    /// Account `bytes` of row data as touched; past the residency budget,
    /// drop the mapping's clean pages and restart the count. Thread-safe:
    /// a racing thread at worst issues one extra (harmless) `madvise`.
    #[inline]
    pub(crate) fn note_touched(&self, bytes: usize) {
        let total = self.touched.fetch_add(bytes, Ordering::Relaxed) + bytes;
        if total >= RESIDENCY_BUDGET_BYTES {
            self.touched.store(0, Ordering::Relaxed);
            // SAFETY: region is alive for as long as self is.
            unsafe { sys::drop_resident(self.region.ptr, self.region.len) };
        }
    }
}

// ---------------------------------------------------------------------------
// Shard file header
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, Default)]
struct Section {
    offset: u64,
    bytes: u64,
    checksum: u64,
}

#[derive(Debug, Clone, Copy)]
struct ShardHeader {
    rows: u64,
    cols: u64,
    nnz: u64,
    shard_index: u64,
    shard_count: u64,
    global_n: u64,
    sections: [Section; SECTIONS],
}

impl ShardHeader {
    fn encode(&self) -> [u8; HEADER_BYTES] {
        let mut buf = [0u8; HEADER_BYTES];
        buf[..8].copy_from_slice(SHARD_MAGIC);
        buf[8..12].copy_from_slice(&SHARD_FORMAT_VERSION.to_le_bytes());
        // bytes 12..16 reserved (zero)
        let mut at = 16;
        for v in [
            self.rows,
            self.cols,
            self.nnz,
            self.shard_index,
            self.shard_count,
            self.global_n,
        ] {
            buf[at..at + 8].copy_from_slice(&v.to_le_bytes());
            at += 8;
        }
        for s in &self.sections {
            for v in [s.offset, s.bytes, s.checksum] {
                buf[at..at + 8].copy_from_slice(&v.to_le_bytes());
                at += 8;
            }
        }
        debug_assert_eq!(at, 184);
        let mut sum = Fnv::new();
        sum.update(&buf[..184]);
        buf[184..192].copy_from_slice(&sum.finish().to_le_bytes());
        buf
    }

    fn decode(path: &Path, buf: &[u8; HEADER_BYTES]) -> Result<ShardHeader> {
        if &buf[..8] != SHARD_MAGIC {
            return Err(shard_err(path, "bad magic: not a cocoa shard file"));
        }
        let version = u32::from_le_bytes(buf[8..12].try_into().unwrap());
        if version != SHARD_FORMAT_VERSION {
            return Err(shard_err(
                path,
                format!("shard format v{version}, this build reads v{SHARD_FORMAT_VERSION}"),
            ));
        }
        let mut sum = Fnv::new();
        sum.update(&buf[..184]);
        let stored = u64::from_le_bytes(buf[184..192].try_into().unwrap());
        if sum.finish() != stored {
            return Err(shard_err(path, "header checksum mismatch (corrupt header)"));
        }
        let read_u64 = |at: usize| u64::from_le_bytes(buf[at..at + 8].try_into().unwrap());
        let mut sections = [Section::default(); SECTIONS];
        for (i, s) in sections.iter_mut().enumerate() {
            let at = 64 + 24 * i;
            *s = Section {
                offset: read_u64(at),
                bytes: read_u64(at + 8),
                checksum: read_u64(at + 16),
            };
        }
        Ok(ShardHeader {
            rows: read_u64(16),
            cols: read_u64(24),
            nnz: read_u64(32),
            shard_index: read_u64(40),
            shard_count: read_u64(48),
            global_n: read_u64(56),
            sections,
        })
    }
}

fn align8(v: u64) -> u64 {
    (v + 7) & !7
}

/// Section layout for a shard of `rows` rows and `nnz` stored entries:
/// indptr (u64), indices (u32), values/labels/norms (f64), each starting
/// 8-aligned. Returns `(offsets, byte_lens, file_len)`.
fn layout(rows: u64, nnz: u64) -> ([u64; SECTIONS], [u64; SECTIONS], u64) {
    let lens = [
        (rows + 1) * 8, // indptr
        nnz * 4,        // indices
        nnz * 8,        // values
        rows * 8,       // labels
        rows * 8,       // norms
    ];
    let mut offsets = [0u64; SECTIONS];
    let mut at = HEADER_BYTES as u64;
    for (i, len) in lens.iter().enumerate() {
        offsets[i] = at;
        at = align8(at + len);
    }
    (offsets, lens, at)
}

// ---------------------------------------------------------------------------
// Writing
// ---------------------------------------------------------------------------

fn f64s_to_bytes(vals: &[f64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() * 8);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// One shard under construction: index/value bytes stream to temp files
/// (with running checksums) so nothing scales with shard nnz in memory;
/// indptr/labels/norms stay in memory (O(rows per shard)).
struct ShardFileBuilder {
    final_path: PathBuf,
    idx_path: PathBuf,
    val_path: PathBuf,
    idx_file: BufWriter<File>,
    val_file: BufWriter<File>,
    idx_sum: Fnv,
    val_sum: Fnv,
    indptr: Vec<u64>,
    labels: Vec<f64>,
    norms: Vec<f64>,
    nnz: u64,
}

impl ShardFileBuilder {
    fn create(dir: &Path, kid: usize) -> Result<ShardFileBuilder> {
        let final_path = dir.join(format!("shard_{kid:04}.bin"));
        let idx_path = dir.join(format!("shard_{kid:04}.idx.tmp"));
        let val_path = dir.join(format!("shard_{kid:04}.val.tmp"));
        let open = |p: &Path| -> Result<BufWriter<File>> {
            Ok(BufWriter::new(
                File::create(p).map_err(|e| shard_err(p, format!("create failed: {e}")))?,
            ))
        };
        Ok(ShardFileBuilder {
            idx_file: open(&idx_path)?,
            val_file: open(&val_path)?,
            final_path,
            idx_path,
            val_path,
            idx_sum: Fnv::new(),
            val_sum: Fnv::new(),
            indptr: vec![0],
            labels: Vec::new(),
            norms: Vec::new(),
            nnz: 0,
        })
    }

    fn push_row(
        &mut self,
        indices: &[u32],
        values: &[f64],
        label: f64,
        norm_sq: f64,
    ) -> Result<()> {
        debug_assert_eq!(indices.len(), values.len());
        let mut idx_bytes = Vec::with_capacity(indices.len() * 4);
        for c in indices {
            idx_bytes.extend_from_slice(&c.to_le_bytes());
        }
        let val_bytes = f64s_to_bytes(values);
        self.idx_sum.update(&idx_bytes);
        self.val_sum.update(&val_bytes);
        let io = |p: &Path, e: std::io::Error| shard_err(p, format!("write failed: {e}"));
        self.idx_file.write_all(&idx_bytes).map_err(|e| io(&self.idx_path, e))?;
        self.val_file.write_all(&val_bytes).map_err(|e| io(&self.val_path, e))?;
        self.nnz += indices.len() as u64;
        self.indptr.push(self.nnz);
        self.labels.push(label);
        self.norms.push(norm_sq);
        Ok(())
    }

    /// Assemble the final shard file (header + sections) and remove the
    /// temp section files.
    fn finish(mut self, cols: u64, kid: u64, k: u64, global_n: u64) -> Result<()> {
        let rows = self.labels.len() as u64;
        let (offsets, lens, file_len) = layout(rows, self.nnz);
        let path = self.final_path.clone();
        let io = |e: std::io::Error| shard_err(&path, format!("write failed: {e}"));
        self.idx_file.flush().map_err(io)?;
        self.val_file.flush().map_err(io)?;
        drop(self.idx_file);
        drop(self.val_file);

        let indptr_bytes: Vec<u8> =
            self.indptr.iter().flat_map(|v| v.to_le_bytes()).collect();
        let labels_bytes = f64s_to_bytes(&self.labels);
        let norms_bytes = f64s_to_bytes(&self.norms);
        let sum_of = |bytes: &[u8]| {
            let mut s = Fnv::new();
            s.update(bytes);
            s.finish()
        };
        let mut sections = [Section::default(); SECTIONS];
        let checks = [
            sum_of(&indptr_bytes),
            self.idx_sum.finish(),
            self.val_sum.finish(),
            sum_of(&labels_bytes),
            sum_of(&norms_bytes),
        ];
        for i in 0..SECTIONS {
            sections[i] = Section { offset: offsets[i], bytes: lens[i], checksum: checks[i] };
        }
        let header = ShardHeader {
            rows,
            cols,
            nnz: self.nnz,
            shard_index: kid,
            shard_count: k,
            global_n,
            sections,
        };

        let mut out = BufWriter::new(File::create(&path).map_err(io)?);
        let mut written = HEADER_BYTES as u64;
        out.write_all(&header.encode()).map_err(io)?;
        let mut copy_section = |out: &mut BufWriter<File>,
                                written: &mut u64,
                                i: usize,
                                bytes: SectionBytes<'_>|
         -> Result<()> {
            debug_assert_eq!(*written, offsets[i]);
            match bytes {
                SectionBytes::Mem(b) => out.write_all(b).map_err(io)?,
                SectionBytes::Tmp(p) => {
                    let f = File::open(p).map_err(|e| shard_err(p, format!("reopen: {e}")))?;
                    std::io::copy(&mut BufReader::new(f), out).map_err(io)?;
                }
            }
            *written += lens[i];
            let pad = align8(*written) - *written;
            out.write_all(&[0u8; 8][..pad as usize]).map_err(io)?;
            *written += pad;
            Ok(())
        };
        copy_section(&mut out, &mut written, SEC_INDPTR, SectionBytes::Mem(&indptr_bytes))?;
        copy_section(&mut out, &mut written, SEC_INDICES, SectionBytes::Tmp(&self.idx_path))?;
        copy_section(&mut out, &mut written, SEC_VALUES, SectionBytes::Tmp(&self.val_path))?;
        copy_section(&mut out, &mut written, SEC_LABELS, SectionBytes::Mem(&labels_bytes))?;
        copy_section(&mut out, &mut written, SEC_NORMS, SectionBytes::Mem(&norms_bytes))?;
        debug_assert_eq!(written, file_len);
        out.flush().map_err(io)?;
        let _ = std::fs::remove_file(&self.idx_path);
        let _ = std::fs::remove_file(&self.val_path);
        Ok(())
    }
}

enum SectionBytes<'a> {
    Mem(&'a [u8]),
    Tmp(&'a Path),
}

/// Streaming shard-set writer: rows arrive once, in global order, and are
/// routed to their partition block's shard on the fly. Peak memory is
/// O(n) scalars (global labels/norms for the manifest fingerprint,
/// per-shard indptr) — never O(nnz).
pub struct ShardSetWriter {
    dir: PathBuf,
    k: usize,
    strategy: PartitionStrategy,
    partition_seed: u64,
    /// Precomputed row -> shard for contiguous/random (empty: round-robin).
    assign: Vec<u32>,
    expected_n: Option<usize>,
    shards: Vec<ShardFileBuilder>,
    labels: Vec<f64>,
    norms: Vec<f64>,
    next_row: usize,
}

impl ShardSetWriter {
    /// Open a writer for `k` shards under `dir` (created if missing).
    /// `n` must be known up front for the contiguous and random
    /// strategies (their block boundaries depend on it); round-robin is
    /// truly single-pass and accepts `None`.
    pub fn create(
        dir: impl AsRef<Path>,
        k: usize,
        strategy: PartitionStrategy,
        partition_seed: u64,
        n: Option<usize>,
    ) -> Result<ShardSetWriter> {
        let dir = dir.as_ref().to_path_buf();
        if k == 0 {
            return Err(shard_err(&dir, "shard count k must be >= 1"));
        }
        std::fs::create_dir_all(&dir)
            .map_err(|e| shard_err(&dir, format!("create dir: {e}")))?;
        let assign = match (strategy, n) {
            (PartitionStrategy::RoundRobin, _) => Vec::new(),
            (_, None) => {
                return Err(shard_err(
                    &dir,
                    format!(
                        "the {} strategy needs the row count up front \
                         (round_robin is the single-pass strategy)",
                        strategy.name()
                    ),
                ))
            }
            (_, Some(n)) => {
                // replicate Partition::new exactly, then invert it: shard
                // k must hold precisely partition block k
                let partition = Partition::new(strategy, n, k, partition_seed);
                let mut assign = vec![0u32; n];
                for (kid, block) in partition.blocks.iter().enumerate() {
                    for &row in block {
                        assign[row as usize] = kid as u32;
                    }
                }
                assign
            }
        };
        let shards = (0..k)
            .map(|kid| ShardFileBuilder::create(&dir, kid))
            .collect::<Result<Vec<_>>>()?;
        Ok(ShardSetWriter {
            dir,
            k,
            strategy,
            partition_seed,
            assign,
            expected_n: n,
            shards,
            labels: Vec::new(),
            norms: Vec::new(),
            next_row: 0,
        })
    }

    /// Append the next global row (rows must arrive in global order).
    /// `indices` must be strictly increasing. `cached_norm_sq` is the
    /// *dataset-cached* `||x_i||^2` (after `normalize_rows` that is
    /// exactly 1.0 for scaled rows) — it feeds only the manifest
    /// fingerprint, so shard-mode runs key the same cached optima as the
    /// in-memory dataset. The norm *stored in the shard file* is
    /// recomputed here from `values`: that matches, bit for bit, what the
    /// in-memory worker path sees (`Dataset::subset` rebuilds norms from
    /// the final values), which is what keeps shard trajectories
    /// identical.
    pub fn push_row(
        &mut self,
        indices: &[u32],
        values: &[f64],
        label: f64,
        cached_norm_sq: f64,
    ) -> Result<()> {
        let i = self.next_row;
        if let Some(n) = self.expected_n {
            if i >= n {
                return Err(shard_err(
                    &self.dir,
                    format!("row {i} pushed but the writer was created for n = {n}"),
                ));
            }
        }
        let kid = match self.strategy {
            PartitionStrategy::RoundRobin => i % self.k,
            _ => self.assign[i] as usize,
        };
        let stored_norm_sq = kernels::sparse_norm_sq(values);
        self.shards[kid].push_row(indices, values, label, stored_norm_sq)?;
        self.labels.push(label);
        self.norms.push(cached_norm_sq);
        self.next_row += 1;
        Ok(())
    }

    /// Rewrite every stored label in place. The LibSVM sharder's
    /// whole-file classification binarization can only run once the last
    /// line has parsed, but must land before the label sections and the
    /// fingerprint are written — labels are O(n) writer state, so this is
    /// cheap and keeps the ingest single-pass over the (big) features.
    pub(crate) fn map_labels(&mut self, f: impl Fn(f64) -> f64) {
        for y in self.labels.iter_mut() {
            *y = f(*y);
        }
        for shard in self.shards.iter_mut() {
            for y in shard.labels.iter_mut() {
                *y = f(*y);
            }
        }
    }

    /// Finalize every shard file and write `manifest.toml`. `cols` is the
    /// global feature dimension d (for LibSVM streams it is only known
    /// once the last line has parsed).
    pub fn finish(self, cols: usize) -> Result<ShardSet> {
        let n = self.next_row;
        if let Some(expected) = self.expected_n {
            if n != expected {
                return Err(shard_err(
                    &self.dir,
                    format!("writer created for n = {expected} but {n} rows were pushed"),
                ));
            }
        }
        if n < self.k {
            return Err(shard_err(
                &self.dir,
                format!("{} shards over {n} rows: at least one shard would be empty", self.k),
            ));
        }
        let nnz: u64 = self.shards.iter().map(|s| s.nnz).sum();
        let fingerprint =
            fingerprint_parts(n, cols, nnz as usize, &self.labels, &self.norms);
        let k = self.k;
        let dir = self.dir.clone();
        for (kid, shard) in self.shards.into_iter().enumerate() {
            shard.finish(cols as u64, kid as u64, k as u64, n as u64)?;
        }
        let manifest = format!(
            "# cocoa shard-set manifest (see docs/DATA.md)\n\
             format_version = {MANIFEST_VERSION}\n\
             n = {n}\n\
             d = {cols}\n\
             nnz = {nnz}\n\
             k = {k}\n\
             strategy = \"{}\"\n\
             partition_seed = {}\n\
             fingerprint = \"{fingerprint}\"\n",
            self.strategy.name(),
            self.partition_seed,
        );
        let mpath = dir.join("manifest.toml");
        std::fs::write(&mpath, manifest)
            .map_err(|e| shard_err(&mpath, format!("write failed: {e}")))?;
        ShardSet::open_with_mode(dir, ShardMode::default_mode())
    }
}

/// Shard an in-memory sparse [`Dataset`] to `dir` — the partition
/// produced by `Partition::new(strategy, n, k, seed)` decides which rows
/// land in which shard. Used by `cocoa shard --synthetic`, tests, and as
/// the reference the streaming LibSVM sharder is property-tested against.
pub fn write_shards(
    data: &Dataset,
    strategy: PartitionStrategy,
    k: usize,
    partition_seed: u64,
    dir: impl AsRef<Path>,
) -> Result<ShardSet> {
    let dir = dir.as_ref();
    let m = match &data.features {
        Features::Sparse(m) => m,
        Features::Dense(_) => {
            return Err(shard_err(
                dir,
                "the shard format is CSR-only; dense datasets stay in-memory \
                 (store them sparse to shard them)",
            ))
        }
    };
    let mut w = ShardSetWriter::create(dir, k, strategy, partition_seed, Some(data.n()))?;
    for i in 0..data.n() {
        let (idx, vals) = m.row_view(i);
        w.push_row(idx, vals, data.labels[i], data.norm_sq(i))?;
    }
    w.finish(data.d())
}

/// Grow an on-disk shard set with `batch` — the durable twin of
/// [`Session::append_rows`](crate::Session::append_rows). Appended row
/// `a` (its 0-based position in the set's **lifetime** append stream,
/// recorded by the manifest's `appended` counter) lands in shard
/// `a % k` — exactly the round-robin routing the live cluster deals
/// appended rows by — and the manifest fingerprint advances by the same
/// order-sensitive chain. A set grown on disk therefore hands worker
/// `k` the same rows, in the same order, with the same stored norms, as
/// a live session that appended the same batches: reopening it trains
/// the identical problem.
///
/// Every shard file is rewritten (a shard whose block gained no rows
/// still needs its header's `global_n` updated), so one call costs a
/// full read + write of the set — append in batches, don't dribble
/// single rows. The rewrite is not crash-atomic: a death mid-append
/// leaves shard headers disagreeing with the manifest, which
/// [`ShardSet::open_shard`] rejects with a typed [`Error::Shard`]
/// instead of training on a half-grown set.
pub fn append_shard_rows(dir: impl AsRef<Path>, batch: &Dataset) -> Result<ShardSet> {
    let dir = dir.as_ref();
    let set = ShardSet::open_with_mode(dir, ShardMode::Owned)?;
    if batch.n() == 0 {
        return Err(shard_err(dir, "append batch has no rows"));
    }
    if batch.d() != set.d {
        return Err(shard_err(
            dir,
            format!("append batch has d = {} but the set has d = {}", batch.d(), set.d),
        ));
    }
    let m = batch.n();
    let n_new = set.n + m;
    // batch row j -> shard (lifetime position) % k, the live routing
    let mut incoming: Vec<Vec<usize>> = vec![Vec::new(); set.k];
    for j in 0..m {
        incoming[(set.appended + j) % set.k].push(j);
    }
    let mut nnz_new = 0u64;
    for (kid, extra) in incoming.iter().enumerate() {
        // Owned mode materializes the old shard fully before finish()
        // truncates its file
        let old = set.open_shard(kid)?;
        let old_m = match &old.features {
            Features::Sparse(mm) => mm,
            Features::Dense(_) => unreachable!("shard files are CSR-only"),
        };
        let mut b = ShardFileBuilder::create(dir, kid)?;
        for i in 0..old.n() {
            let (idx, vals) = old_m.row_view(i);
            b.push_row(idx, vals, old.labels[i], old.norm_sq(i))?;
        }
        for &j in extra {
            let (own_idx, own_val);
            let (idx, vals): (&[u32], &[f64]) = match &batch.features {
                Features::Sparse(mm) => mm.row_view(j),
                Features::Dense(mm) => {
                    // densified rows shed exact-zero bits, like the live
                    // AppendBlock (w . x and the stored norm are unchanged)
                    let row = mm.row(j);
                    let mut ii = Vec::new();
                    let mut vv = Vec::new();
                    for (c, &v) in row.iter().enumerate() {
                        if v.to_bits() != 0 {
                            ii.push(c as u32);
                            vv.push(v);
                        }
                    }
                    own_idx = ii;
                    own_val = vv;
                    (&own_idx, &own_val)
                }
            };
            // store the batch's cached norm — what the live append ships
            // to workers — so disk-grown and live-grown blocks agree bit
            // for bit
            b.push_row(idx, vals, batch.labels[j], batch.norm_sq(j))?;
        }
        nnz_new += b.nnz;
        b.finish(set.d as u64, kid as u64, set.k as u64, n_new as u64)?;
    }
    let fingerprint = super::fingerprint_chain(&set.fingerprint, &batch.fingerprint());
    let appended = set.appended + m;
    let manifest = format!(
        "# cocoa shard-set manifest (see docs/DATA.md)\n\
         format_version = {MANIFEST_VERSION}\n\
         n = {n_new}\n\
         d = {}\n\
         nnz = {nnz_new}\n\
         k = {}\n\
         strategy = \"{}\"\n\
         partition_seed = {}\n\
         appended = {appended}\n\
         fingerprint = \"{fingerprint}\"\n",
        set.d,
        set.k,
        set.strategy.name(),
        set.partition_seed,
    );
    let mpath = dir.join("manifest.toml");
    std::fs::write(&mpath, manifest)
        .map_err(|e| shard_err(&mpath, format!("write failed: {e}")))?;
    ShardSet::open_with_mode(dir, ShardMode::default_mode())
}

// ---------------------------------------------------------------------------
// Reading
// ---------------------------------------------------------------------------

/// How [`ShardSet::open_shard`] backs the index/value sections.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardMode {
    /// `mmap` the file; rows fault in on demand and a residency budget
    /// keeps peak RSS bounded. Falls back to [`ShardMode::Owned`] when
    /// [`mmap_supported`] is false.
    Mapped,
    /// Read the sections into ordinary `Vec`s (same verified bytes).
    Owned,
}

impl ShardMode {
    /// Mapped where the platform supports it, Owned elsewhere.
    pub fn default_mode() -> ShardMode {
        if mmap_supported() {
            ShardMode::Mapped
        } else {
            ShardMode::Owned
        }
    }
}

/// An opened shard-set directory: the parsed manifest plus the mode used
/// to open individual shards. This is the data half of a shard-mode
/// [`crate::Trainer`]: the leader reads only the manifest (n, d,
/// fingerprint, partition recipe); each worker opens exactly its own
/// shard file.
#[derive(Debug, Clone)]
pub struct ShardSet {
    dir: PathBuf,
    n: usize,
    d: usize,
    nnz: u64,
    k: usize,
    strategy: PartitionStrategy,
    partition_seed: u64,
    appended: usize,
    fingerprint: String,
    mode: ShardMode,
}

impl ShardSet {
    /// Open `dir/manifest.toml` with the platform-default [`ShardMode`].
    pub fn open(dir: impl AsRef<Path>) -> Result<ShardSet> {
        ShardSet::open_with_mode(dir, ShardMode::default_mode())
    }

    /// Open with an explicit mode (`[data] mmap = false` forces Owned).
    pub fn open_with_mode(dir: impl AsRef<Path>, mode: ShardMode) -> Result<ShardSet> {
        let dir = dir.as_ref().to_path_buf();
        let mpath = dir.join("manifest.toml");
        let text = std::fs::read_to_string(&mpath)
            .map_err(|e| shard_err(&mpath, format!("read failed: {e}")))?;
        let doc = Doc::parse(&text)
            .map_err(|e| shard_err(&mpath, format!("manifest parse failed: {e:#}")))?;
        let version = doc.usize_or("", "format_version", 0);
        if version != MANIFEST_VERSION as usize {
            return Err(shard_err(
                &mpath,
                format!("manifest v{version}, this build reads v{MANIFEST_VERSION}"),
            ));
        }
        let field = |key: &str| -> Result<usize> {
            doc.get("", key).and_then(crate::util::toml_lite::Value::as_usize).ok_or_else(
                || shard_err(&mpath, format!("manifest is missing integer key {key:?}")),
            )
        };
        let n = field("n")?;
        let d = field("d")?;
        let nnz = field("nnz")? as u64;
        let k = field("k")?;
        let strategy_name = doc
            .get("", "strategy")
            .and_then(crate::util::toml_lite::Value::as_str)
            .ok_or_else(|| shard_err(&mpath, "manifest is missing string key \"strategy\""))?;
        let strategy = PartitionStrategy::from_name(strategy_name).ok_or_else(|| {
            shard_err(&mpath, format!("unknown partition strategy {strategy_name:?}"))
        })?;
        let partition_seed = doc.u64_or("", "partition_seed", 0);
        // rows grown onto the set after it was written (absent on sets
        // that never saw `append_shard_rows`)
        let appended = doc.usize_or("", "appended", 0);
        let fingerprint = doc
            .get("", "fingerprint")
            .and_then(crate::util::toml_lite::Value::as_str)
            .ok_or_else(|| shard_err(&mpath, "manifest is missing string key \"fingerprint\""))?
            .to_string();
        if k == 0 || n == 0 || d == 0 || k > n {
            return Err(shard_err(
                &mpath,
                format!("manifest shape is degenerate (n = {n}, d = {d}, k = {k})"),
            ));
        }
        if appended >= n || n - appended < k {
            return Err(shard_err(
                &mpath,
                format!("manifest appended = {appended} leaves no base partition (n = {n}, k = {k})"),
            ));
        }
        let mode = match mode {
            ShardMode::Mapped if !mmap_supported() => ShardMode::Owned,
            m => m,
        };
        let set = ShardSet {
            dir,
            n,
            d,
            nnz,
            k,
            strategy,
            partition_seed,
            appended,
            fingerprint,
            mode,
        };
        for kid in 0..k {
            let p = set.shard_path(kid);
            if !p.exists() {
                return Err(shard_err(&p, "manifest names a shard file that does not exist"));
            }
        }
        Ok(set)
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn nnz(&self) -> u64 {
        self.nnz
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn mode(&self) -> ShardMode {
        self.mode
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Rows grown onto the set by [`append_shard_rows`] after it was
    /// first written (the manifest's lifetime append counter).
    pub fn appended(&self) -> usize {
        self.appended
    }

    /// The full-dataset content fingerprint: `Dataset::fingerprint` of
    /// the dataset that was sharded, advanced by the append chain for
    /// every batch grown on since — what the net handshake binds to.
    pub fn fingerprint(&self) -> &str {
        &self.fingerprint
    }

    /// Reconstruct the partition the shards were written under: the
    /// strategy partition over the base rows, with every appended row
    /// `a` (lifetime append-stream position) dealt onto block `a % k` —
    /// the same routing the live cluster uses, so a disk-grown set and
    /// a live-grown session agree on who owns which row.
    pub fn partition(&self) -> Partition {
        let base = self.n - self.appended;
        let mut blocks =
            Partition::new(self.strategy, base, self.k, self.partition_seed).blocks;
        for a in 0..self.appended {
            blocks[a % self.k].push((base + a) as u32);
        }
        Partition::from_blocks(blocks, self.n)
    }

    pub fn shard_path(&self, kid: usize) -> PathBuf {
        self.dir.join(format!("shard_{kid:04}.bin"))
    }

    /// Total on-disk bytes across all shard files (the `dataset_bytes`
    /// the `_ooc` BENCH entries compare peak RSS against).
    pub fn total_bytes(&self) -> u64 {
        (0..self.k)
            .filter_map(|kid| std::fs::metadata(self.shard_path(kid)).ok())
            .map(|m| m.len())
            .sum()
    }

    /// Open shard `kid` as a worker-local [`Dataset`]: verify every
    /// section checksum and the CSR invariants with buffered streaming
    /// reads, then back the index/value sections per [`ShardSet::mode`].
    /// The returned dataset is bit-identical (labels, norms, row views)
    /// to `full_dataset.subset(&partition.blocks[kid])`.
    pub fn open_shard(&self, kid: usize) -> Result<Dataset> {
        if kid >= self.k {
            return Err(shard_err(
                &self.dir,
                format!("shard index {kid} out of range (k = {})", self.k),
            ));
        }
        let path = self.shard_path(kid);
        let file =
            File::open(&path).map_err(|e| shard_err(&path, format!("open failed: {e}")))?;
        let file_len = file
            .metadata()
            .map_err(|e| shard_err(&path, format!("stat failed: {e}")))?
            .len();
        let mut reader = BufReader::with_capacity(256 << 10, file);

        let mut hbuf = [0u8; HEADER_BYTES];
        reader
            .read_exact(&mut hbuf)
            .map_err(|e| shard_err(&path, format!("truncated header: {e}")))?;
        let header = ShardHeader::decode(&path, &hbuf)?;
        let rows = header.rows as usize;
        let cols = header.cols as usize;
        let nnz = header.nnz as usize;
        if header.shard_index != kid as u64
            || header.shard_count != self.k as u64
            || header.global_n != self.n as u64
            || cols != self.d
        {
            return Err(shard_err(
                &path,
                format!(
                    "shard/manifest mismatch: file says shard {}/{} of n = {}, d = {}; \
                     manifest says shard {kid}/{} of n = {}, d = {}",
                    header.shard_index,
                    header.shard_count,
                    header.global_n,
                    cols,
                    self.k,
                    self.n,
                    self.d
                ),
            ));
        }
        let (offsets, lens, expect_len) = layout(header.rows, header.nnz);
        for (i, s) in header.sections.iter().enumerate() {
            if s.offset != offsets[i] || s.bytes != lens[i] {
                return Err(shard_err(&path, "section table disagrees with the shard shape"));
            }
        }
        if file_len != expect_len {
            return Err(shard_err(
                &path,
                format!("file is {file_len} bytes, layout expects {expect_len} (truncated?)"),
            ));
        }

        // --- streaming verification + owned loads of the small sections.
        // Buffered reads go through the page cache, not the process RSS
        // ledger, so verification never costs what it verifies.
        let mut read_section = |i: usize, want_pad: bool| -> Result<Vec<u8>> {
            let mut bytes = vec![0u8; lens[i] as usize];
            reader
                .read_exact(&mut bytes)
                .map_err(|e| shard_err(&path, format!("truncated section {i}: {e}")))?;
            let mut sum = Fnv::new();
            sum.update(&bytes);
            if sum.finish() != header.sections[i].checksum {
                return Err(shard_err(
                    &path,
                    format!("section {i} checksum mismatch (corrupt shard)"),
                ));
            }
            if want_pad {
                let pad = (align8(offsets[i] + lens[i]) - (offsets[i] + lens[i])) as usize;
                let mut padbuf = [0u8; 8];
                reader
                    .read_exact(&mut padbuf[..pad])
                    .map_err(|e| shard_err(&path, format!("truncated padding: {e}")))?;
            }
            Ok(bytes)
        };

        let indptr_bytes = read_section(SEC_INDPTR, true)?;
        let mut indptr = Vec::with_capacity(rows + 1);
        for chunk in indptr_bytes.chunks_exact(8) {
            indptr.push(u64::from_le_bytes(chunk.try_into().unwrap()) as usize);
        }
        drop(indptr_bytes);
        if indptr.first() != Some(&0)
            || indptr.last() != Some(&nnz)
            || indptr.windows(2).any(|w| w[1] < w[0])
        {
            return Err(shard_err(&path, "indptr is not a monotone 0..nnz row index"));
        }

        let idx_bytes = read_section(SEC_INDICES, true)?;
        let mut indices: Vec<u32> = Vec::with_capacity(nnz);
        for chunk in idx_bytes.chunks_exact(4) {
            indices.push(u32::from_le_bytes(chunk.try_into().unwrap()));
        }
        drop(idx_bytes);
        for r in 0..rows {
            let row = &indices[indptr[r]..indptr[r + 1]];
            if row.iter().any(|&c| c as usize >= cols) {
                return Err(shard_err(
                    &path,
                    format!("row {r} has a column index >= d = {cols}"),
                ));
            }
            if row.windows(2).any(|w| w[1] <= w[0]) {
                return Err(shard_err(
                    &path,
                    format!("row {r} indices are not strictly increasing"),
                ));
            }
        }

        let val_bytes = read_section(SEC_VALUES, false)?;
        let mut values: Vec<f64> = Vec::with_capacity(nnz);
        for chunk in val_bytes.chunks_exact(8) {
            values.push(f64::from_le_bytes(chunk.try_into().unwrap()));
        }
        drop(val_bytes);
        if values.iter().any(|v| !v.is_finite()) {
            return Err(shard_err(&path, "values section contains a non-finite number"));
        }

        let to_f64s = |bytes: Vec<u8>| -> Vec<f64> {
            bytes
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                .collect()
        };
        let labels = to_f64s(read_section(SEC_LABELS, false)?);
        let norms = to_f64s(read_section(SEC_NORMS, false)?);
        if labels.iter().chain(&norms).any(|v| !v.is_finite()) {
            return Err(shard_err(&path, "labels/norms contain a non-finite number"));
        }

        let matrix = match self.mode {
            ShardMode::Owned => CsrMatrix::from_validated_parts(rows, cols, indptr, indices, values),
            ShardMode::Mapped => {
                // every byte was just verified; now map the file and keep
                // only the two big sections behind the mapping
                drop(values);
                drop(indices);
                let mut file = reader.into_inner();
                file.rewind()
                    .map_err(|e| shard_err(&path, format!("rewind failed: {e}")))?;
                match sys::map_file(&file, file_len as usize) {
                    Some(ptr) => {
                        let region = Arc::new(MapRegion { ptr, len: file_len as usize });
                        let mapped = MappedCsr {
                            region,
                            idx_off: offsets[SEC_INDICES] as usize,
                            idx_len: nnz,
                            val_off: offsets[SEC_VALUES] as usize,
                            val_len: nnz,
                            touched: AtomicUsize::new(0),
                        };
                        CsrMatrix::from_mapped(rows, cols, indptr, mapped)
                    }
                    None => {
                        return Err(shard_err(&path, "mmap failed (out of address space?)"))
                    }
                }
            }
        };
        Ok(Dataset::with_norms(Features::Sparse(matrix), labels, norms))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rcv1_like;

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("cocoa_mmap_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn roundtrip_matches_subset_bitwise() {
        let data = rcv1_like(120, 60, 5, 0.1, 3);
        let dir = tmpdir("roundtrip");
        let set = write_shards(&data, PartitionStrategy::Contiguous, 3, 0, &dir).unwrap();
        assert_eq!(set.fingerprint(), data.fingerprint());
        assert_eq!(set.nnz() as usize, data.nnz());
        let partition = set.partition();
        for mode in [ShardMode::Owned, ShardMode::Mapped] {
            let set = ShardSet::open_with_mode(&dir, mode).unwrap();
            for kid in 0..3 {
                let shard = set.open_shard(kid).unwrap();
                let reference = data.subset(&partition.blocks[kid]);
                assert_eq!(shard.labels, reference.labels);
                assert_eq!(shard.n(), reference.n());
                for i in 0..shard.n() {
                    assert_eq!(shard.norm_sq(i).to_bits(), reference.norm_sq(i).to_bits());
                    assert_eq!(
                        shard.features.row_dense(i),
                        reference.features.row_dense(i),
                        "shard {kid} row {i}"
                    );
                }
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn random_strategy_replicates_partition_assignment() {
        let data = rcv1_like(90, 40, 4, 0.1, 5);
        let dir = tmpdir("random");
        let set = write_shards(&data, PartitionStrategy::Random, 4, 99, &dir).unwrap();
        let partition = set.partition();
        for kid in 0..4 {
            let shard = set.open_shard(kid).unwrap();
            let reference = data.subset(&partition.blocks[kid]);
            assert_eq!(shard.labels, reference.labels, "shard {kid}");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_and_truncated_shards_are_rejected_typed() {
        let data = rcv1_like(80, 30, 4, 0.1, 11);
        let dir = tmpdir("corrupt");
        let set = write_shards(&data, PartitionStrategy::RoundRobin, 2, 0, &dir).unwrap();
        let path = set.shard_path(1);
        let pristine = std::fs::read(&path).unwrap();

        // flip one byte deep in the values section
        let mut bad = pristine.clone();
        let at = bad.len() - 24;
        bad[at] ^= 0x40;
        std::fs::write(&path, &bad).unwrap();
        let err = set.open_shard(1).unwrap_err();
        assert!(matches!(err, Error::Shard { .. }), "{err}");
        assert!(err.to_string().contains("checksum"), "{err}");

        // truncate the file
        std::fs::write(&path, &pristine[..pristine.len() / 2]).unwrap();
        let err = set.open_shard(1).unwrap_err();
        assert!(matches!(err, Error::Shard { .. }), "{err}");

        // garbage magic
        let mut bad = pristine.clone();
        bad[0] = b'X';
        std::fs::write(&path, &bad).unwrap();
        let err = set.open_shard(1).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");

        std::fs::write(&path, &pristine).unwrap();
        set.open_shard(1).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn manifest_mismatches_are_rejected() {
        let data = rcv1_like(50, 20, 3, 0.1, 2);
        let dir = tmpdir("manifest");
        write_shards(&data, PartitionStrategy::Contiguous, 2, 0, &dir).unwrap();
        let mpath = dir.join("manifest.toml");
        let text = std::fs::read_to_string(&mpath).unwrap();
        std::fs::write(&mpath, text.replace("n = 50", "n = 49")).unwrap();
        // manifest n disagrees with the shard headers' global_n
        let set = ShardSet::open(&dir).unwrap();
        assert!(matches!(set.open_shard(0).unwrap_err(), Error::Shard { .. }));
        std::fs::write(&mpath, text.replace("format_version = 1", "format_version = 9")).unwrap();
        assert!(matches!(ShardSet::open(&dir).unwrap_err(), Error::Shard { .. }));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn writer_validates_shape() {
        let dir = tmpdir("shape");
        // contiguous needs n up front
        assert!(ShardSetWriter::create(&dir, 2, PartitionStrategy::Contiguous, 0, None).is_err());
        // more shards than rows
        let mut w =
            ShardSetWriter::create(&dir, 3, PartitionStrategy::RoundRobin, 0, None).unwrap();
        w.push_row(&[0], &[1.0], 1.0, 1.0).unwrap();
        assert!(matches!(w.finish(4).unwrap_err(), Error::Shard { .. }));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dense_datasets_are_refused() {
        let dense = crate::data::cov_like(10, 3, 0.0, 1);
        let dir = tmpdir("dense");
        let err = write_shards(&dense, PartitionStrategy::Contiguous, 2, 0, &dir).unwrap_err();
        assert!(err.to_string().contains("CSR-only"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn append_grows_shards_round_robin_and_chains_fingerprint() {
        let base = rcv1_like(60, 30, 4, 0.1, 7);
        let batch = rcv1_like(10, 30, 4, 0.1, 8);
        let dir = tmpdir("append");
        let set = write_shards(&base, PartitionStrategy::RoundRobin, 3, 0, &dir).unwrap();
        let base_partition = set.partition();
        let base_fp = set.fingerprint().to_string();

        let grown = append_shard_rows(&dir, &batch).unwrap();
        assert_eq!(grown.n(), 70);
        assert_eq!(grown.appended(), 10);
        assert_eq!(grown.nnz() as usize, base.nnz() + batch.nnz());
        assert_eq!(
            grown.fingerprint(),
            crate::data::fingerprint_chain(&base_fp, &batch.fingerprint())
        );

        // partition = base blocks + appended row a on block a % k
        let partition = grown.partition();
        partition.validate().unwrap();
        for kid in 0..3 {
            let tail: Vec<u32> =
                (0..10u32).filter(|a| (*a as usize) % 3 == kid).map(|a| 60 + a).collect();
            assert_eq!(partition.blocks[kid][base_partition.blocks[kid].len()..], tail[..]);
        }

        // each shard = old shard rows followed by its appended rows, with
        // the batch's cached norms stored bit-for-bit
        for mode in [ShardMode::Owned, ShardMode::Mapped] {
            let grown = ShardSet::open_with_mode(&dir, mode).unwrap();
            for kid in 0..3 {
                let shard = grown.open_shard(kid).unwrap();
                let old = base.subset(&base_partition.blocks[kid]);
                assert_eq!(shard.n(), partition.blocks[kid].len());
                for i in 0..old.n() {
                    assert_eq!(shard.labels[i], old.labels[i]);
                    assert_eq!(shard.norm_sq(i).to_bits(), old.norm_sq(i).to_bits());
                    assert_eq!(shard.features.row_dense(i), old.features.row_dense(i));
                }
                for (t, j) in (0..10).filter(|j| j % 3 == kid).enumerate() {
                    let i = old.n() + t;
                    assert_eq!(shard.labels[i], batch.labels[j]);
                    assert_eq!(shard.norm_sq(i).to_bits(), batch.norm_sq(j).to_bits());
                    assert_eq!(
                        shard.features.row_dense(i),
                        batch.features.row_dense(j),
                        "shard {kid} appended row {j}"
                    );
                }
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn second_append_continues_the_lifetime_stream() {
        let base = rcv1_like(20, 15, 3, 0.1, 1);
        let dir = tmpdir("append_twice");
        write_shards(&base, PartitionStrategy::Contiguous, 2, 0, &dir).unwrap();
        append_shard_rows(&dir, &rcv1_like(3, 15, 3, 0.1, 2)).unwrap();
        let grown = append_shard_rows(&dir, &rcv1_like(4, 15, 3, 0.1, 3)).unwrap();
        assert_eq!(grown.n(), 27);
        assert_eq!(grown.appended(), 7);
        // lifetime stream positions 0..7 deal 20+a onto block a % 2,
        // regardless of the batch boundary after position 2
        let partition = grown.partition();
        partition.validate().unwrap();
        let tail0: Vec<u32> = (0..7u32).filter(|a| a % 2 == 0).map(|a| 20 + a).collect();
        let tail1: Vec<u32> = (0..7u32).filter(|a| a % 2 == 1).map(|a| 20 + a).collect();
        assert!(partition.blocks[0].ends_with(&tail0));
        assert!(partition.blocks[1].ends_with(&tail1));
        // every shard opens clean (headers agree with the rewritten manifest)
        for kid in 0..2 {
            grown.open_shard(kid).unwrap();
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn append_validates_batch_shape() {
        let base = rcv1_like(20, 15, 3, 0.1, 4);
        let dir = tmpdir("append_shape");
        write_shards(&base, PartitionStrategy::RoundRobin, 2, 0, &dir).unwrap();
        let err = append_shard_rows(&dir, &rcv1_like(5, 9, 3, 0.1, 5)).unwrap_err();
        assert!(err.to_string().contains("d = 9"), "{err}");
        let empty = Dataset::new(
            Features::Sparse(crate::data::sparse::CsrMatrix::from_triplets(0, 15, &[])),
            vec![],
        );
        let err = append_shard_rows(&dir, &empty).unwrap_err();
        assert!(err.to_string().contains("no rows"), "{err}");
        // failed appends leave the set intact
        let set = ShardSet::open(&dir).unwrap();
        assert_eq!(set.n(), 20);
        assert_eq!(set.appended(), 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
