//! Datasets, storage formats, loaders, generators, and coordinate
//! partitioning — the substrate under every experiment in the paper.
//!
//! Data lives in row-major form (one row per training example `x_i`); the
//! paper's rescaled column matrix `A_i = x_i / (lambda n)` is never
//! materialized — solvers fold the `1/(lambda n)` factor into their updates.
//!
//! Two storage paths feed the solvers, and they are bit-identical by
//! construction (see `docs/DATA.md` for the full contract):
//!
//! * **in-memory** — [`read_libsvm`] / the synthetic generators build a
//!   [`Dataset`] whose CSR arrays are owned `Vec`s;
//! * **out-of-core** — [`shard_libsvm`] (streaming) or [`write_shards`]
//!   (from memory) split the rows into per-worker shard files, and
//!   [`ShardSet`] reopens them `mmap`-backed so a worker's peak RSS stays
//!   bounded far below the dataset size (module [`mmap`]).
//!
//! ```
//! use cocoa::data::{rcv1_like, write_shards, PartitionStrategy};
//! use cocoa::prelude::*;
//!
//! let data = rcv1_like(60, 30, 4, 0.1, 3);
//! let dir = std::env::temp_dir().join("cocoa_doc_data_mod");
//! let _ = std::fs::remove_dir_all(&dir);
//! let set = write_shards(&data, PartitionStrategy::Contiguous, 2, 0, &dir).unwrap();
//! // the same builder, on shards instead of a Dataset — K comes
//! // from the manifest, workers open only their own shard file
//! let mut session = Trainer::on_shards(&set)
//!     .loss(LossKind::Hinge)
//!     .lambda(0.05)
//!     .build()
//!     .unwrap();
//! let trace = session.run(&mut Cocoa::new(30), MaxRounds::new(2)).unwrap();
//! assert_eq!(trace.rows.last().unwrap().round, 2);
//! let _ = std::fs::remove_dir_all(&dir);
//! ```

mod dense;
mod gzip;
mod libsvm;
pub mod mmap;
mod partition;
mod sparse;
mod synthetic;

pub use dense::DenseMatrix;
pub use libsvm::{read_libsvm, shard_libsvm, write_libsvm};
pub use mmap::{append_shard_rows, mmap_supported, write_shards, ShardMode, ShardSet, ShardSetWriter};
pub use partition::{Partition, PartitionStrategy};
pub use sparse::CsrMatrix;
pub use synthetic::{
    cov_like, imagenet_like, kdd_stream_shards, orthogonal_blocks, rcv1_like,
    rcv1_stream_shards, url_stream_shards, SyntheticSpec,
};

/// Feature storage: dense row-major or CSR. All solver hot paths go
/// through the row accessors here, so both formats run every algorithm.
#[derive(Debug, Clone)]
pub enum Features {
    Dense(DenseMatrix),
    Sparse(CsrMatrix),
}

impl Features {
    pub fn rows(&self) -> usize {
        match self {
            Features::Dense(m) => m.rows,
            Features::Sparse(m) => m.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Features::Dense(m) => m.cols,
            Features::Sparse(m) => m.cols(),
        }
    }

    /// Number of stored (potentially non-zero) entries.
    pub fn nnz(&self) -> usize {
        match self {
            Features::Dense(m) => m.data.len(),
            Features::Sparse(m) => m.nnz(),
        }
    }

    /// `x_i . w` — the margin, the single hottest operation in the system.
    #[inline]
    pub fn row_dot(&self, i: usize, w: &[f64]) -> f64 {
        match self {
            Features::Dense(m) => m.row_dot(i, w),
            Features::Sparse(m) => m.row_dot(i, w),
        }
    }

    /// `out += coef * x_i` — the rank-1 primal update.
    #[inline]
    pub fn add_row_scaled(&self, i: usize, coef: f64, out: &mut [f64]) {
        match self {
            Features::Dense(m) => m.add_row_scaled(i, coef, out),
            Features::Sparse(m) => m.add_row_scaled(i, coef, out),
        }
    }

    /// `||x_i||^2`.
    pub fn row_norm_sq(&self, i: usize) -> f64 {
        match self {
            Features::Dense(m) => m.row_norm_sq(i),
            Features::Sparse(m) => m.row_norm_sq(i),
        }
    }

    /// Dense copy of row `i` (marshalling into PJRT literals, tests).
    pub fn row_dense(&self, i: usize) -> Vec<f64> {
        let mut out = vec![0.0; self.cols()];
        self.add_row_scaled(i, 1.0, &mut out);
        out
    }

    /// In-place scale of row `i` (used by normalization).
    fn scale_row(&mut self, i: usize, s: f64) {
        match self {
            Features::Dense(m) => m.scale_row(i, s),
            Features::Sparse(m) => m.scale_row(i, s),
        }
    }

    /// Append rows given in CSR form (continuous training). Sparse
    /// storage extends its arrays (materializing mmap-backed storage
    /// first — the shard file on disk stays immutable); dense storage
    /// densifies each row. `indptr` is batch-local (`rows + 1` entries
    /// starting at 0).
    pub(crate) fn append_csr_rows(
        &mut self,
        indptr: &[usize],
        indices: &[u32],
        values: &[f64],
    ) -> Result<(), String> {
        match self {
            Features::Sparse(m) => m.append_csr_rows(indptr, indices, values),
            Features::Dense(m) => {
                if indptr.is_empty() || indptr[0] != 0 {
                    return Err("append indptr must start at 0".into());
                }
                if *indptr.last().expect("checked non-empty") != indices.len()
                    || indices.len() != values.len()
                {
                    return Err("append arrays disagree".into());
                }
                if let Some(c) = indices.iter().find(|&&c| c as usize >= m.cols) {
                    return Err(format!("append index {} >= cols {}", c, m.cols));
                }
                // validated — mutate only now, so a bad batch never
                // leaves a half-appended matrix behind
                let rows = indptr.len() - 1;
                m.data.reserve(rows * m.cols);
                for win in indptr.windows(2) {
                    let start = m.data.len();
                    m.data.resize(start + m.cols, 0.0);
                    let row = &mut m.data[start..];
                    for (c, v) in indices[win[0]..win[1]].iter().zip(&values[win[0]..win[1]]) {
                        row[*c as usize] = *v;
                    }
                }
                m.rows += rows;
                Ok(())
            }
        }
    }
}

/// A labelled dataset for problem (1): features + labels, with cached row
/// norms (`||x_i||^2`), reused by every solver step and the sigma_min
/// estimator.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub features: Features,
    pub labels: Vec<f64>,
    norms_sq: Vec<f64>,
}

impl Dataset {
    pub fn new(features: Features, labels: Vec<f64>) -> Self {
        assert_eq!(
            features.rows(),
            labels.len(),
            "feature rows must match label count"
        );
        let norms_sq = (0..features.rows()).map(|i| features.row_norm_sq(i)).collect();
        Dataset { features, labels, norms_sq }
    }

    /// Construct with norms the caller already holds (the shard open
    /// path: norms were cached at shard-write time, so reopening never
    /// pages the value section just to recompute them — and the cached
    /// bits match what [`Dataset::new`] would compute, keeping shard and
    /// in-memory trajectories identical).
    pub(crate) fn with_norms(
        features: Features,
        labels: Vec<f64>,
        norms_sq: Vec<f64>,
    ) -> Self {
        assert_eq!(features.rows(), labels.len(), "feature rows must match label count");
        assert_eq!(features.rows(), norms_sq.len(), "feature rows must match norm count");
        Dataset { features, labels, norms_sq }
    }

    pub fn n(&self) -> usize {
        self.labels.len()
    }

    pub fn d(&self) -> usize {
        self.features.cols()
    }

    pub fn nnz(&self) -> usize {
        self.features.nnz()
    }

    /// Stored-entry density in [0,1].
    pub fn density(&self) -> f64 {
        let cells = (self.n() as f64) * (self.d() as f64);
        if cells == 0.0 { 0.0 } else { self.nnz() as f64 / cells }
    }

    #[inline]
    pub fn norm_sq(&self, i: usize) -> f64 {
        self.norms_sq[i]
    }

    /// Scale every row to `||x_i|| <= 1`, the paper's standing assumption
    /// (Section 4). Rows already inside the ball are left untouched.
    pub fn normalize_rows(&mut self) {
        for i in 0..self.n() {
            let norm = self.norms_sq[i].sqrt();
            if norm > 1.0 {
                self.features.scale_row(i, 1.0 / norm);
                self.norms_sq[i] = 1.0;
            }
        }
    }

    /// Largest `||x_i||^2` — 1.0 after normalization.
    pub fn max_norm_sq(&self) -> f64 {
        self.norms_sq.iter().cloned().fold(0.0, f64::max)
    }

    /// Materialize the sub-dataset for the rows in `idx` (a worker block).
    pub fn subset(&self, idx: &[u32]) -> Dataset {
        let labels: Vec<f64> = idx.iter().map(|&i| self.labels[i as usize]).collect();
        let features = match &self.features {
            Features::Dense(m) => Features::Dense(m.subset(idx)),
            Features::Sparse(m) => Features::Sparse(m.subset(idx)),
        };
        Dataset::new(features, labels)
    }

    /// `w = A alpha = (1/(lambda n)) sum_i alpha_i x_i` — the dual-to-primal
    /// map (Section 2).
    pub fn primal_from_dual(&self, alpha: &[f64], lambda: f64) -> Vec<f64> {
        assert_eq!(alpha.len(), self.n());
        let mut w = vec![0.0; self.d()];
        let scale = 1.0 / (lambda * self.n() as f64);
        for (i, &a) in alpha.iter().enumerate() {
            if a != 0.0 {
                self.features.add_row_scaled(i, a * scale, &mut w);
            }
        }
        w
    }

    /// A short stable fingerprint of shape + content used to key cached
    /// optima on disk.
    pub fn fingerprint(&self) -> String {
        fingerprint_parts(self.n(), self.d(), self.nnz(), &self.labels, &self.norms_sq)
    }

    /// Append rows given in CSR form with their labels and *cached*
    /// norms (continuous training). Shipping the cached norms — rather
    /// than recomputing from `values` — keeps an appended dataset
    /// bit-identical to one built whole (e.g. after [`normalize_rows`],
    /// where the cache holds exactly 1.0 but a recomputed norm need
    /// not). `indptr` is batch-local (`rows + 1` entries starting at 0).
    ///
    /// [`normalize_rows`]: Dataset::normalize_rows
    pub(crate) fn append_csr_rows(
        &mut self,
        indptr: &[usize],
        indices: &[u32],
        values: &[f64],
        labels: &[f64],
        norms_sq: &[f64],
    ) -> Result<(), String> {
        if indptr.len() != labels.len() + 1 || labels.len() != norms_sq.len() {
            return Err(format!(
                "append rows disagree: indptr for {} rows, {} labels, {} norms",
                indptr.len().saturating_sub(1),
                labels.len(),
                norms_sq.len()
            ));
        }
        self.features.append_csr_rows(indptr, indices, values)?;
        self.labels.extend_from_slice(labels);
        self.norms_sq.extend_from_slice(norms_sq);
        Ok(())
    }
}

/// [`Dataset::fingerprint`] from its raw ingredients — the shard writer
/// computes the same string without a `Dataset` in memory, and a
/// shard-mode leader/worker reads it straight from `manifest.toml`, so
/// the net handshake binds to identical fingerprints on both paths.
pub(crate) fn fingerprint_parts(
    n: usize,
    d: usize,
    nnz: usize,
    labels: &[f64],
    norms_sq: &[f64],
) -> String {
    // FNV-1a over a deterministic sample of entries: cheap and stable.
    let mut h: u64 = 0xcbf29ce484222325;
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(0x100000001b3);
    };
    mix(n as u64);
    mix(d as u64);
    mix(nnz as u64);
    let step = (n / 64).max(1);
    for i in (0..n).step_by(step) {
        mix(labels[i].to_bits());
        mix(norms_sq[i].to_bits());
    }
    format!("{h:016x}")
}

/// Chain a base fingerprint with an appended batch's fingerprint. A
/// grown dataset's identity is the *history* of appends, not a function
/// of the final bytes: the live append path (`Session::append_rows`) and
/// the durable one (`append_shard_rows`) both chain the same way, so a
/// serving handshake bound to either stays consistent — and a scorer
/// holding a pre-append snapshot is recognizably stale.
pub(crate) fn fingerprint_chain(base: &str, batch: &str) -> String {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in base.bytes().chain(batch.bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    format!("{h:016x}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        let m = DenseMatrix::from_rows(&[
            vec![3.0, 4.0],
            vec![0.5, 0.0],
            vec![0.0, 0.0],
        ]);
        Dataset::new(Features::Dense(m), vec![1.0, -1.0, 1.0])
    }

    #[test]
    fn norms_cached() {
        let ds = toy();
        assert_eq!(ds.norm_sq(0), 25.0);
        assert_eq!(ds.norm_sq(1), 0.25);
        assert_eq!(ds.norm_sq(2), 0.0);
    }

    #[test]
    fn normalize_caps_at_unit_ball() {
        let mut ds = toy();
        ds.normalize_rows();
        assert!((ds.norm_sq(0) - 1.0).abs() < 1e-12);
        // rows already inside the ball are untouched
        assert_eq!(ds.norm_sq(1), 0.25);
        assert!(ds.max_norm_sq() <= 1.0 + 1e-12);
    }

    #[test]
    fn primal_from_dual_matches_manual() {
        let ds = toy();
        let lambda = 0.5;
        let w = ds.primal_from_dual(&[1.0, 2.0, 0.0], lambda);
        let scale = 1.0 / (lambda * 3.0);
        assert!((w[0] - (3.0 + 1.0) * scale).abs() < 1e-12);
        assert!((w[1] - 4.0 * scale).abs() < 1e-12);
    }

    #[test]
    fn subset_preserves_rows() {
        let ds = toy();
        let sub = ds.subset(&[2, 0]);
        assert_eq!(sub.n(), 2);
        assert_eq!(sub.labels, vec![1.0, 1.0]);
        assert_eq!(sub.features.row_dense(0), vec![0.0, 0.0]);
        assert_eq!(sub.features.row_dense(1), vec![3.0, 4.0]);
    }

    #[test]
    fn fingerprint_changes_with_data() {
        let a = toy().fingerprint();
        let mut other = toy();
        other.labels[0] = -1.0;
        assert_ne!(a, other.fingerprint());
        assert_eq!(a, toy().fingerprint());
    }
}
