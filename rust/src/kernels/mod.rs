//! Fused kernels for the solver hot path — the innermost dots, axpys,
//! scaled updates, and norms every inner SDCA step runs — with runtime
//! feature-detected SIMD backends over a scalar reference.
//!
//! Two design rules govern everything in this module:
//!
//! 1. **Bit-exact accumulation order.** Each kernel documents the exact
//!    floating-point reduction order it commits to, and never deviates
//!    from it. The sparse kernels accumulate strictly left-to-right into a
//!    single chain (identical to the naive `for` loop they replace), so
//!    every seeded trajectory in the repo — the determinism gates, the
//!    golden suites — is bit-for-bit unchanged by routing through them.
//!    The dense kernels keep the 8-lane blocked order the dense hot path
//!    has used since the L3 perf iteration (see [`scalar::dense_dot`]).
//!    The SIMD backends ([`simd`]) map those lane accumulators onto
//!    vector lanes one-to-one and replay the same combine tree — no FMA,
//!    no reassociation — so **every backend produces identical bits**,
//!    and backend selection can never change a trajectory.
//! 2. **Checked by construction, not per element.** The `*_unchecked`
//!    gather kernels elide the per-element bounds check of the naive loop.
//!    Their safety contract — every index is in bounds for the gathered
//!    slice — is owned by [`crate::data::CsrMatrix`], whose constructors
//!    validate `index < cols` once and whose fields are private so the
//!    invariant cannot be broken afterwards. The safe wrappers
//!    ([`sparse_dot`], [`sparse_axpy`], [`dense_dot`], [`dense_axpy`])
//!    validate per call — with real `assert`s, active in release builds
//!    too, because a silent truncation returns a *wrong* answer — and
//!    exist for callers outside that invariant.
//!
//! Backend selection runs once per process ([`backend`]): AVX2 when
//! `is_x86_feature_detected!("avx2")` says so, NEON on aarch64 (part of
//! the target baseline), scalar otherwise — or everywhere when the
//! `COCOA_SIMD=off` environment variable forces the reference path.
//! The property suite (`rust/tests/prop_kernels.rs`) pins rule 1: every
//! dispatched kernel is compared bit-for-bit against the scalar
//! reference on random and adversarial inputs (empty rows, `len % 8 != 0`
//! remainders, subnormals).

pub mod scalar;
pub mod simd;

use std::sync::OnceLock;

/// Which kernel implementation [`backend`] selected for this process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// The scalar reference kernels ([`scalar`]).
    Scalar,
    /// AVX2 dense + sparse-gather kernels (x86_64, runtime-detected).
    Avx2,
    /// NEON dense kernels (aarch64 baseline).
    Neon,
}

impl Backend {
    /// Stable lowercase name, reported in `BENCH_hotpath.json`.
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2 => "avx2",
            Backend::Neon => "neon",
        }
    }
}

static BACKEND: OnceLock<Backend> = OnceLock::new();

/// The kernel backend this process dispatches to — detected once, cached
/// for the process lifetime (so a trajectory can never mix backends).
pub fn backend() -> Backend {
    *BACKEND.get_or_init(detect)
}

/// [`backend`]'s stable name (`"scalar"` / `"avx2"` / `"neon"`).
pub fn backend_name() -> &'static str {
    backend().name()
}

fn detect() -> Backend {
    // escape hatch: COCOA_SIMD=off pins the scalar reference path (used
    // by the property suite's cross-backend runs and for bisecting)
    if let Some(v) = std::env::var_os("COCOA_SIMD") {
        if v == "off" || v == "0" || v == "scalar" {
            return Backend::Scalar;
        }
    }
    detect_arch()
}

#[cfg(target_arch = "x86_64")]
fn detect_arch() -> Backend {
    if std::arch::is_x86_feature_detected!("avx2") {
        Backend::Avx2
    } else {
        Backend::Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn detect_arch() -> Backend {
    Backend::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect_arch() -> Backend {
    Backend::Scalar
}

/// 8-lane blocked dense dot product (see [`scalar::dense_dot`] for the
/// reduction-order contract), dispatched to the detected SIMD backend —
/// all backends are bit-identical by construction.
///
/// Validates `a.len() == b.len()` per call (release builds included: a
/// mismatched pair used to silently truncate to the shorter slice).
#[inline]
pub fn dense_dot(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "dense_dot: length mismatch");
    match backend() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: backend() returned Avx2 only after runtime detection,
        // and lengths were just checked equal.
        Backend::Avx2 => unsafe { simd::avx2::dense_dot(a, b) },
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => simd::neon::dense_dot(a, b),
        _ => scalar::dense_dot(a, b),
    }
}

/// `out += coef * a`, blocked like [`dense_dot`] and dispatched the same
/// way (element updates are independent, so blocking never changes bits).
///
/// Validates `a.len() == out.len()` per call (release builds included).
#[inline]
pub fn dense_axpy(coef: f64, a: &[f64], out: &mut [f64]) {
    assert_eq!(a.len(), out.len(), "dense_axpy: length mismatch");
    match backend() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: backend() returned Avx2 only after runtime detection,
        // and lengths were just checked equal.
        Backend::Avx2 => unsafe { simd::avx2::dense_axpy(coef, a, out) },
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => simd::neon::dense_axpy(coef, a, out),
        _ => scalar::dense_axpy(coef, a, out),
    }
}

/// `||a||^2` with the [`dense_dot`] reduction order (the cached-row-norm
/// kernel; bit-identical to `dense_dot(a, a)`).
#[inline]
pub fn dense_norm_sq(a: &[f64]) -> f64 {
    dense_dot(a, a)
}

/// Sparse gather-dot: `sum_k values[k] * w[indices[k]]` with a strictly
/// left-to-right add chain (see [`scalar::sparse_dot_unchecked`]). On
/// AVX2 the four products per unroll are gathered and multiplied in one
/// vector op — the adds stay scalar-chained, so bits never change.
///
/// # Safety
/// Every `indices[k] as usize` must be `< w.len()`. [`crate::data::CsrMatrix`]
/// guarantees this for its rows against any `w` of length `>= cols`.
#[inline]
pub unsafe fn sparse_dot_unchecked(indices: &[u32], values: &[f64], w: &[f64]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    // the i32 gather needs every index to fit a non-negative i32; any
    // in-bounds index does once w.len() <= i32::MAX
    if backend() == Backend::Avx2 && w.len() <= i32::MAX as usize {
        return simd::avx2::sparse_dot_unchecked(indices, values, w);
    }
    scalar::sparse_dot_unchecked(indices, values, w)
}

/// Safe wrapper over [`sparse_dot_unchecked`]: validates every index per
/// call (O(nnz) integer compares), then runs the fused kernel.
#[inline]
pub fn sparse_dot(indices: &[u32], values: &[f64], w: &[f64]) -> f64 {
    assert_eq!(indices.len(), values.len(), "index/value length mismatch");
    assert!(
        indices.iter().all(|&i| (i as usize) < w.len()),
        "sparse_dot: index out of bounds for target of length {}",
        w.len()
    );
    // SAFETY: every index was just checked against w.len().
    unsafe { sparse_dot_unchecked(indices, values, w) }
}

/// Sparse scatter-axpy: `out[indices[k]] += coef * values[k]`, strictly
/// left to right (see [`scalar::sparse_axpy_unchecked`]). Always scalar:
/// the RMW chain must preserve order even under repeated indices, and no
/// AVX2 scatter exists to vectorize the stores anyway.
///
/// # Safety
/// Every `indices[k] as usize` must be `< out.len()` (see
/// [`sparse_dot_unchecked`]).
#[inline]
pub unsafe fn sparse_axpy_unchecked(indices: &[u32], values: &[f64], coef: f64, out: &mut [f64]) {
    scalar::sparse_axpy_unchecked(indices, values, coef, out)
}

/// Safe wrapper over [`sparse_axpy_unchecked`]: validates every index per
/// call, then runs the fused kernel.
#[inline]
pub fn sparse_axpy(indices: &[u32], values: &[f64], coef: f64, out: &mut [f64]) {
    assert_eq!(indices.len(), values.len(), "index/value length mismatch");
    assert!(
        indices.iter().all(|&i| (i as usize) < out.len()),
        "sparse_axpy: index out of bounds for target of length {}",
        out.len()
    );
    // SAFETY: every index was just checked against out.len().
    unsafe { sparse_axpy_unchecked(indices, values, coef, out) }
}

/// nnz-aware squared norm of a sparse row (see
/// [`scalar::sparse_norm_sq`]; always scalar — the add chain is the
/// whole kernel).
#[inline]
pub fn sparse_norm_sq(values: &[f64]) -> f64 {
    scalar::sparse_norm_sq(values)
}

/// In-place scaled update `values[k] *= s` (row normalization; each
/// element independent, order-free).
#[inline]
pub fn scale_in_place(values: &mut [f64], s: f64) {
    for v in values.iter_mut() {
        *v *= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_sparse_dot(indices: &[u32], values: &[f64], w: &[f64]) -> f64 {
        let mut s = 0.0;
        for (i, v) in indices.iter().zip(values) {
            s += v * w[*i as usize];
        }
        s
    }

    #[test]
    fn sparse_dot_matches_naive_bitwise() {
        let idx = [0u32, 3, 4, 7, 9, 11, 12];
        let val = [0.5, -1.25, 3.0, 0.1, -0.7, 2.5, 1.0 / 3.0];
        let w: Vec<f64> = (0..13).map(|i| ((i * 37) as f64).sin()).collect();
        let a = sparse_dot(&idx, &val, &w);
        let b = naive_sparse_dot(&idx, &val, &w);
        assert_eq!(a.to_bits(), b.to_bits());
    }

    #[test]
    fn sparse_kernels_handle_empty_rows() {
        let w = [1.0, 2.0];
        assert_eq!(sparse_dot(&[], &[], &w), 0.0);
        let mut out = [1.0, 2.0];
        sparse_axpy(&[], &[], 5.0, &mut out);
        assert_eq!(out, [1.0, 2.0]);
        assert_eq!(sparse_norm_sq(&[]), 0.0);
    }

    #[test]
    fn sparse_axpy_matches_naive_bitwise() {
        let idx = [1u32, 2, 5, 6, 8];
        let val = [0.3, -0.9, 1.5, 1.0 / 7.0, -2.25];
        let mut a = vec![0.125f64; 10];
        let mut b = a.clone();
        sparse_axpy(&idx, &val, 0.7, &mut a);
        for (i, v) in idx.iter().zip(&val) {
            b[*i as usize] += 0.7 * v;
        }
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    #[should_panic(expected = "index out of bounds")]
    fn safe_wrapper_rejects_out_of_bounds() {
        sparse_dot(&[4], &[1.0], &[0.0; 3]);
    }

    // The satellite-fix regression tests: the dense safe wrappers must
    // reject length mismatches in *every* build profile — before the
    // promotion to real asserts, a release build silently truncated to
    // the shorter slice and returned a wrong answer. ci.sh runs the
    // kernel suite under --release so these exercise the release path.
    #[test]
    #[should_panic(expected = "dense_dot: length mismatch")]
    fn dense_dot_rejects_length_mismatch_in_all_profiles() {
        dense_dot(&[1.0, 2.0, 3.0], &[1.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "dense_axpy: length mismatch")]
    fn dense_axpy_rejects_length_mismatch_in_all_profiles() {
        let mut out = [0.0; 2];
        dense_axpy(1.0, &[1.0, 2.0, 3.0], &mut out);
    }

    #[test]
    fn norm_matches_iterator_sum_bitwise() {
        let vals: Vec<f64> = (0..11).map(|i| ((i * 13) as f64).cos() * 1.7).collect();
        let naive: f64 = vals.iter().map(|v| v * v).sum();
        assert_eq!(sparse_norm_sq(&vals).to_bits(), naive.to_bits());
    }

    #[test]
    fn dense_dot_matches_blocked_reference_bitwise() {
        // reference: the documented 8-lane order written as plain loops
        let a: Vec<f64> = (0..21).map(|i| (i as f64 * 0.31).sin()).collect();
        let b: Vec<f64> = (0..21).map(|i| (i as f64 * 0.17).cos()).collect();
        let mut lanes = [0.0f64; 8];
        let main = a.len() / 8 * 8;
        for k in 0..main {
            lanes[k % 8] += a[k] * b[k];
        }
        let mut s = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
            + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
        for k in main..a.len() {
            s += a[k] * b[k];
        }
        assert_eq!(dense_dot(&a, &b).to_bits(), s.to_bits());
    }

    #[test]
    fn dispatched_backend_matches_scalar_reference_bitwise() {
        // whatever backend() picked on this machine, the dispatched
        // kernels must equal the scalar reference bit-for-bit (trivially
        // true when the pick *is* scalar; the real cross-check on
        // AVX2/NEON hosts)
        for len in [0usize, 1, 7, 8, 9, 15, 16, 31, 64] {
            let a: Vec<f64> = (0..len).map(|i| (i as f64 * 0.37).sin() * 1.5).collect();
            let b: Vec<f64> = (0..len).map(|i| (i as f64 * 0.73).cos() - 0.2).collect();
            assert_eq!(
                dense_dot(&a, &b).to_bits(),
                scalar::dense_dot(&a, &b).to_bits(),
                "dense_dot backend {} diverged at len {len}",
                backend_name()
            );
            let mut o1: Vec<f64> = (0..len).map(|i| i as f64 * 0.01 - 0.3).collect();
            let mut o2 = o1.clone();
            dense_axpy(-1.75, &a, &mut o1);
            scalar::dense_axpy(-1.75, &a, &mut o2);
            for (x, y) in o1.iter().zip(&o2) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn backend_is_cached_and_named() {
        let b = backend();
        assert_eq!(b, backend(), "backend must be stable per process");
        assert!(["scalar", "avx2", "neon"].contains(&backend_name()));
    }

    #[test]
    fn scale_in_place_scales() {
        let mut v = vec![1.0, -2.0, 0.5];
        scale_in_place(&mut v, 2.0);
        assert_eq!(v, vec![2.0, -4.0, 1.0]);
    }
}
