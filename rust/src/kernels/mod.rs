//! Fused scalar kernels for the solver hot path — the innermost dots,
//! axpys, scaled updates, and norms every inner SDCA step runs.
//!
//! Two design rules govern everything in this module:
//!
//! 1. **Bit-exact accumulation order.** Each kernel documents the exact
//!    floating-point reduction order it commits to, and never deviates
//!    from it. The sparse kernels accumulate strictly left-to-right into a
//!    single chain (identical to the naive `for` loop they replace), so
//!    every seeded trajectory in the repo — the determinism gates, the
//!    golden suites — is bit-for-bit unchanged by routing through them.
//!    The dense kernels keep the 8-lane blocked order the dense hot path
//!    has used since the L3 perf iteration (see `dense_dot`). Unrolling
//!    here buys instruction-level parallelism on the *loads* (index
//!    gather, value fetch) without reassociating the FP adds.
//! 2. **Checked by construction, not per element.** The `*_unchecked`
//!    gather kernels elide the per-element bounds check of the naive loop.
//!    Their safety contract — every index is in bounds for the gathered
//!    slice — is owned by [`crate::data::CsrMatrix`], whose constructors
//!    validate `index < cols` once and whose fields are private so the
//!    invariant cannot be broken afterwards. The safe wrappers
//!    ([`sparse_dot`], [`sparse_axpy`]) validate per call and exist for
//!    callers outside that invariant (tests, external users).
//!
//! The property suite (`rust/tests/prop_kernels.rs`) pins rule 1: every
//! fused kernel is compared bit-for-bit against a naive scalar reference
//! on random sparse/dense inputs, including empty rows.

/// 8-lane blocked dense dot product. `chunks_exact(8)` gives LLVM a
/// fixed-width body it fully vectorizes without `-ffast-math`-style
/// reassociation; measured 1.6x over the naive zip/sum and 2.1x over a
/// 4-accumulator manual unroll at the d=54 hot shape, 4.1x at d=1024
/// (EXPERIMENTS.md section Perf, iteration L3-1).
///
/// Reduction order (the bit-exactness contract): 8 independent lane
/// accumulators over the `len / 8 * 8` prefix, combined as
/// `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`, then the remainder folded in
/// left to right.
#[inline]
pub fn dense_dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 8];
    let ca = a.chunks_exact(8);
    let cb = b.chunks_exact(8);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        for k in 0..8 {
            acc[k] += xa[k] * xb[k];
        }
    }
    let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3]))
        + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for (x, y) in ra.iter().zip(rb) {
        s += x * y;
    }
    s
}

/// `out += coef * a`, blocked like [`dense_dot`] (iteration L3-2: +24% on
/// the d=54 axpy, neutral at d >= 256 where it is memory-bound). Each
/// element update is independent, so the blocking never changes bits.
#[inline]
pub fn dense_axpy(coef: f64, a: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), out.len());
    let ca = a.chunks_exact(8);
    let ra = ca.remainder();
    let co = out.chunks_exact_mut(8);
    for (xo, xa) in co.zip(ca) {
        for k in 0..8 {
            xo[k] += coef * xa[k];
        }
    }
    let tail = out.len() - ra.len();
    for (o, &v) in out[tail..].iter_mut().zip(ra.iter()) {
        *o += coef * v;
    }
}

/// `||a||^2` with the [`dense_dot`] reduction order (the cached-row-norm
/// kernel; bit-identical to `dense_dot(a, a)`).
#[inline]
pub fn dense_norm_sq(a: &[f64]) -> f64 {
    dense_dot(a, a)
}

/// Sparse gather-dot: `sum_k values[k] * w[indices[k]]`, unrolled by 4.
///
/// Reduction order: a single accumulator, strictly left to right — the
/// unroll computes four products ahead (independent rounded ops) but
/// chains the adds sequentially, so the result is bit-identical to the
/// naive `for (i, v) in indices.zip(values) { s += v * w[i] }` loop.
///
/// # Safety
/// Every `indices[k] as usize` must be `< w.len()`. [`crate::data::CsrMatrix`]
/// guarantees this for its rows against any `w` of length `>= cols`.
#[inline]
pub unsafe fn sparse_dot_unchecked(indices: &[u32], values: &[f64], w: &[f64]) -> f64 {
    debug_assert_eq!(indices.len(), values.len());
    debug_assert!(indices.iter().all(|&i| (i as usize) < w.len()));
    let n = indices.len();
    let mut s = 0.0f64;
    let mut k = 0usize;
    while k + 4 <= n {
        let p0 = *values.get_unchecked(k)
            * *w.get_unchecked(*indices.get_unchecked(k) as usize);
        let p1 = *values.get_unchecked(k + 1)
            * *w.get_unchecked(*indices.get_unchecked(k + 1) as usize);
        let p2 = *values.get_unchecked(k + 2)
            * *w.get_unchecked(*indices.get_unchecked(k + 2) as usize);
        let p3 = *values.get_unchecked(k + 3)
            * *w.get_unchecked(*indices.get_unchecked(k + 3) as usize);
        // strictly sequential adds: never reassociated
        s += p0;
        s += p1;
        s += p2;
        s += p3;
        k += 4;
    }
    while k < n {
        s += *values.get_unchecked(k)
            * *w.get_unchecked(*indices.get_unchecked(k) as usize);
        k += 1;
    }
    s
}

/// Safe wrapper over [`sparse_dot_unchecked`]: validates every index per
/// call (O(nnz) integer compares), then runs the fused kernel.
#[inline]
pub fn sparse_dot(indices: &[u32], values: &[f64], w: &[f64]) -> f64 {
    assert_eq!(indices.len(), values.len(), "index/value length mismatch");
    assert!(
        indices.iter().all(|&i| (i as usize) < w.len()),
        "sparse_dot: index out of bounds for target of length {}",
        w.len()
    );
    // SAFETY: every index was just checked against w.len().
    unsafe { sparse_dot_unchecked(indices, values, w) }
}

/// Sparse scatter-axpy: `out[indices[k]] += coef * values[k]`, unrolled
/// by 4. Updates run strictly left to right (a read-modify-write per
/// element), so rows with repeated indices still fold in the naive order
/// and the result is bit-identical to the scalar loop.
///
/// # Safety
/// Every `indices[k] as usize` must be `< out.len()` (see
/// [`sparse_dot_unchecked`]).
#[inline]
pub unsafe fn sparse_axpy_unchecked(indices: &[u32], values: &[f64], coef: f64, out: &mut [f64]) {
    debug_assert_eq!(indices.len(), values.len());
    debug_assert!(indices.iter().all(|&i| (i as usize) < out.len()));
    let n = indices.len();
    let mut k = 0usize;
    while k + 4 <= n {
        *out.get_unchecked_mut(*indices.get_unchecked(k) as usize) +=
            coef * *values.get_unchecked(k);
        *out.get_unchecked_mut(*indices.get_unchecked(k + 1) as usize) +=
            coef * *values.get_unchecked(k + 1);
        *out.get_unchecked_mut(*indices.get_unchecked(k + 2) as usize) +=
            coef * *values.get_unchecked(k + 2);
        *out.get_unchecked_mut(*indices.get_unchecked(k + 3) as usize) +=
            coef * *values.get_unchecked(k + 3);
        k += 4;
    }
    while k < n {
        *out.get_unchecked_mut(*indices.get_unchecked(k) as usize) +=
            coef * *values.get_unchecked(k);
        k += 1;
    }
}

/// Safe wrapper over [`sparse_axpy_unchecked`]: validates every index per
/// call, then runs the fused kernel.
#[inline]
pub fn sparse_axpy(indices: &[u32], values: &[f64], coef: f64, out: &mut [f64]) {
    assert_eq!(indices.len(), values.len(), "index/value length mismatch");
    assert!(
        indices.iter().all(|&i| (i as usize) < out.len()),
        "sparse_axpy: index out of bounds for target of length {}",
        out.len()
    );
    // SAFETY: every index was just checked against out.len().
    unsafe { sparse_axpy_unchecked(indices, values, coef, out) }
}

/// nnz-aware squared norm of a sparse row: `sum_k values[k]^2`, single
/// accumulator left to right (bit-identical to `values.iter().map(|v| v *
/// v).sum()` — iterator `sum` folds sequentially from 0.0).
#[inline]
pub fn sparse_norm_sq(values: &[f64]) -> f64 {
    let mut s = 0.0f64;
    let mut k = 0usize;
    let n = values.len();
    while k + 4 <= n {
        let p0 = values[k] * values[k];
        let p1 = values[k + 1] * values[k + 1];
        let p2 = values[k + 2] * values[k + 2];
        let p3 = values[k + 3] * values[k + 3];
        s += p0;
        s += p1;
        s += p2;
        s += p3;
        k += 4;
    }
    while k < n {
        s += values[k] * values[k];
        k += 1;
    }
    s
}

/// In-place scaled update `values[k] *= s` (row normalization; each
/// element independent, order-free).
#[inline]
pub fn scale_in_place(values: &mut [f64], s: f64) {
    for v in values.iter_mut() {
        *v *= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_sparse_dot(indices: &[u32], values: &[f64], w: &[f64]) -> f64 {
        let mut s = 0.0;
        for (i, v) in indices.iter().zip(values) {
            s += v * w[*i as usize];
        }
        s
    }

    #[test]
    fn sparse_dot_matches_naive_bitwise() {
        let idx = [0u32, 3, 4, 7, 9, 11, 12];
        let val = [0.5, -1.25, 3.0, 0.1, -0.7, 2.5, 1.0 / 3.0];
        let w: Vec<f64> = (0..13).map(|i| ((i * 37) as f64).sin()).collect();
        let a = sparse_dot(&idx, &val, &w);
        let b = naive_sparse_dot(&idx, &val, &w);
        assert_eq!(a.to_bits(), b.to_bits());
    }

    #[test]
    fn sparse_kernels_handle_empty_rows() {
        let w = [1.0, 2.0];
        assert_eq!(sparse_dot(&[], &[], &w), 0.0);
        let mut out = [1.0, 2.0];
        sparse_axpy(&[], &[], 5.0, &mut out);
        assert_eq!(out, [1.0, 2.0]);
        assert_eq!(sparse_norm_sq(&[]), 0.0);
    }

    #[test]
    fn sparse_axpy_matches_naive_bitwise() {
        let idx = [1u32, 2, 5, 6, 8];
        let val = [0.3, -0.9, 1.5, 1.0 / 7.0, -2.25];
        let mut a = vec![0.125f64; 10];
        let mut b = a.clone();
        sparse_axpy(&idx, &val, 0.7, &mut a);
        for (i, v) in idx.iter().zip(&val) {
            b[*i as usize] += 0.7 * v;
        }
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    #[should_panic(expected = "index out of bounds")]
    fn safe_wrapper_rejects_out_of_bounds() {
        sparse_dot(&[4], &[1.0], &[0.0; 3]);
    }

    #[test]
    fn norm_matches_iterator_sum_bitwise() {
        let vals: Vec<f64> = (0..11).map(|i| ((i * 13) as f64).cos() * 1.7).collect();
        let naive: f64 = vals.iter().map(|v| v * v).sum();
        assert_eq!(sparse_norm_sq(&vals).to_bits(), naive.to_bits());
    }

    #[test]
    fn dense_dot_matches_blocked_reference_bitwise() {
        // reference: the documented 8-lane order written as plain loops
        let a: Vec<f64> = (0..21).map(|i| (i as f64 * 0.31).sin()).collect();
        let b: Vec<f64> = (0..21).map(|i| (i as f64 * 0.17).cos()).collect();
        let mut lanes = [0.0f64; 8];
        let main = a.len() / 8 * 8;
        for k in 0..main {
            lanes[k % 8] += a[k] * b[k];
        }
        let mut s = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
            + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
        for k in main..a.len() {
            s += a[k] * b[k];
        }
        assert_eq!(dense_dot(&a, &b).to_bits(), s.to_bits());
    }

    #[test]
    fn scale_in_place_scales() {
        let mut v = vec![1.0, -2.0, 0.5];
        scale_in_place(&mut v, 2.0);
        assert_eq!(v, vec![2.0, -4.0, 1.0]);
    }
}
