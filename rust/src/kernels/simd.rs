//! Explicit SIMD kernel backends (AVX2 on x86_64, NEON on aarch64),
//! selected at runtime by [`crate::kernels::backend`].
//!
//! Every function here is a drop-in for its scalar counterpart in
//! [`super::scalar`] and must be **bit-identical** to it — the SIMD lanes
//! are arranged so each scalar lane accumulator maps to exactly one
//! vector lane, the lane combine replays the documented scalar reduction
//! tree, and no FMA is ever emitted (a fused multiply-add rounds once
//! where `mul` + `add` round twice, which would change bits). The
//! property suite compares these against the scalar reference bitwise on
//! adversarial shapes (empty, `len % 8 != 0` remainders, subnormals).
//!
//! What is (and is not) vectorized:
//!
//! * `dense_dot` / `dense_axpy`: full-width SIMD. The scalar versions
//!   already use an 8-lane blocked order, so two 4-wide (AVX2) or four
//!   2-wide (NEON) vector accumulators reproduce it exactly.
//! * `sparse_dot`: AVX2 vectorizes the 4 gathered products per unroll
//!   (`vgatherdpd` + one `mul`); the adds stay a strictly sequential
//!   scalar chain — that order is the kernel's documented contract, so
//!   only the (independently rounded) products may be vectorized.
//! * `sparse_axpy`, `sparse_norm_sq`: stay scalar everywhere. The
//!   scatter is a strictly ordered read-modify-write chain (repeated
//!   indices must fold in order; no AVX2 scatter exists anyway) and the
//!   norm's chained adds leave nothing but the squares to vectorize —
//!   measured neutral, not worth a second code path.

#[cfg(target_arch = "x86_64")]
pub mod avx2 {
    //! AVX2 (+ the AVX it implies) backend. Callers must have verified
    //! `is_x86_feature_detected!("avx2")` — the dispatcher does.
    use core::arch::x86_64::{
        __m128i, _mm256_add_pd, _mm256_i32gather_pd, _mm256_loadu_pd, _mm256_mul_pd,
        _mm256_set1_pd, _mm256_setzero_pd, _mm256_storeu_pd, _mm_loadu_si128,
    };

    /// [`crate::kernels::scalar::dense_dot`], AVX2. Lanes 0–3 and 4–7 of
    /// the scalar 8-lane accumulator live in two `__m256d` registers;
    /// the combine extracts the 8 lanes and replays
    /// `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))` in scalar, then folds the
    /// remainder left to right. `mul` + `add`, never FMA.
    ///
    /// # Safety
    /// The CPU must support AVX2, and `a.len() == b.len()` (the
    /// dispatcher validates both).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dense_dot(a: &[f64], b: &[f64]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let main = n / 8 * 8;
        let mut acc_lo = _mm256_setzero_pd();
        let mut acc_hi = _mm256_setzero_pd();
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut k = 0usize;
        while k < main {
            let a_lo = _mm256_loadu_pd(pa.add(k));
            let b_lo = _mm256_loadu_pd(pb.add(k));
            let a_hi = _mm256_loadu_pd(pa.add(k + 4));
            let b_hi = _mm256_loadu_pd(pb.add(k + 4));
            acc_lo = _mm256_add_pd(acc_lo, _mm256_mul_pd(a_lo, b_lo));
            acc_hi = _mm256_add_pd(acc_hi, _mm256_mul_pd(a_hi, b_hi));
            k += 8;
        }
        let mut lo = [0.0f64; 4];
        let mut hi = [0.0f64; 4];
        _mm256_storeu_pd(lo.as_mut_ptr(), acc_lo);
        _mm256_storeu_pd(hi.as_mut_ptr(), acc_hi);
        let mut s = ((lo[0] + lo[1]) + (lo[2] + lo[3]))
            + ((hi[0] + hi[1]) + (hi[2] + hi[3]));
        while k < n {
            s += *a.get_unchecked(k) * *b.get_unchecked(k);
            k += 1;
        }
        s
    }

    /// [`crate::kernels::scalar::dense_axpy`], AVX2. Element updates are
    /// independent, so any blocking is bit-safe; this one mirrors the
    /// scalar 8-block (two 4-wide `mul` + `add` per block, no FMA) with a
    /// scalar left-to-right tail.
    ///
    /// # Safety
    /// The CPU must support AVX2, and `a.len() == out.len()` (the
    /// dispatcher validates both).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dense_axpy(coef: f64, a: &[f64], out: &mut [f64]) {
        debug_assert_eq!(a.len(), out.len());
        let n = a.len();
        let main = n / 8 * 8;
        let c = _mm256_set1_pd(coef);
        let pa = a.as_ptr();
        let po = out.as_mut_ptr();
        let mut k = 0usize;
        while k < main {
            let o_lo = _mm256_loadu_pd(po.add(k));
            let o_hi = _mm256_loadu_pd(po.add(k + 4));
            let a_lo = _mm256_loadu_pd(pa.add(k));
            let a_hi = _mm256_loadu_pd(pa.add(k + 4));
            _mm256_storeu_pd(po.add(k), _mm256_add_pd(o_lo, _mm256_mul_pd(c, a_lo)));
            _mm256_storeu_pd(po.add(k + 4), _mm256_add_pd(o_hi, _mm256_mul_pd(c, a_hi)));
            k += 8;
        }
        while k < n {
            *out.get_unchecked_mut(k) += coef * *a.get_unchecked(k);
            k += 1;
        }
    }

    /// [`crate::kernels::scalar::sparse_dot_unchecked`], AVX2: the four
    /// products of each unroll come from one `vgatherdpd` + one `mul`;
    /// the accumulator adds stay a strictly sequential scalar chain (the
    /// documented reduction order), so bits never change — each product
    /// is a single rounded multiply either way.
    ///
    /// # Safety
    /// The CPU must support AVX2; every `indices[k] as usize` must be
    /// `< w.len()`; and `w.len() <= i32::MAX as usize` so each u32 index
    /// is a non-negative i32 for the gather (the dispatcher checks the
    /// length and falls back to scalar otherwise).
    #[target_feature(enable = "avx2")]
    pub unsafe fn sparse_dot_unchecked(indices: &[u32], values: &[f64], w: &[f64]) -> f64 {
        debug_assert_eq!(indices.len(), values.len());
        debug_assert!(w.len() <= i32::MAX as usize);
        debug_assert!(indices.iter().all(|&i| (i as usize) < w.len()));
        let n = indices.len();
        let mut s = 0.0f64;
        let mut k = 0usize;
        while k + 4 <= n {
            let vidx = _mm_loadu_si128(indices.as_ptr().add(k) as *const __m128i);
            let vals = _mm256_loadu_pd(values.as_ptr().add(k));
            let gathered = _mm256_i32gather_pd::<8>(w.as_ptr(), vidx);
            let mut p = [0.0f64; 4];
            _mm256_storeu_pd(p.as_mut_ptr(), _mm256_mul_pd(vals, gathered));
            // strictly sequential adds: never reassociated
            s += p[0];
            s += p[1];
            s += p[2];
            s += p[3];
            k += 4;
        }
        while k < n {
            s += *values.get_unchecked(k)
                * *w.get_unchecked(*indices.get_unchecked(k) as usize);
            k += 1;
        }
        s
    }
}

#[cfg(target_arch = "aarch64")]
pub mod neon {
    //! NEON backend. NEON is part of the aarch64 baseline (every
    //! `aarch64-*` std target compiles with it on), so these are safe
    //! functions — no runtime detection needed.
    use core::arch::aarch64::{
        vaddq_f64, vdupq_n_f64, vgetq_lane_f64, vld1q_f64, vmulq_f64, vst1q_f64,
    };

    /// [`crate::kernels::scalar::dense_dot`], NEON. The scalar 8-lane
    /// accumulator lives in four 2-wide vector registers (lanes (0,1),
    /// (2,3), (4,5), (6,7)); the combine extracts all 8 lanes and replays
    /// the scalar reduction tree. `vmulq` + `vaddq`, never `vfmaq`.
    pub fn dense_dot(a: &[f64], b: &[f64]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let main = n / 8 * 8;
        // SAFETY: all loads stay inside the `main` prefix of both
        // slices; NEON is in the aarch64 target baseline.
        unsafe {
            let mut acc01 = vdupq_n_f64(0.0);
            let mut acc23 = vdupq_n_f64(0.0);
            let mut acc45 = vdupq_n_f64(0.0);
            let mut acc67 = vdupq_n_f64(0.0);
            let (pa, pb) = (a.as_ptr(), b.as_ptr());
            let mut k = 0usize;
            while k < main {
                acc01 = vaddq_f64(acc01, vmulq_f64(vld1q_f64(pa.add(k)), vld1q_f64(pb.add(k))));
                acc23 = vaddq_f64(
                    acc23,
                    vmulq_f64(vld1q_f64(pa.add(k + 2)), vld1q_f64(pb.add(k + 2))),
                );
                acc45 = vaddq_f64(
                    acc45,
                    vmulq_f64(vld1q_f64(pa.add(k + 4)), vld1q_f64(pb.add(k + 4))),
                );
                acc67 = vaddq_f64(
                    acc67,
                    vmulq_f64(vld1q_f64(pa.add(k + 6)), vld1q_f64(pb.add(k + 6))),
                );
                k += 8;
            }
            let mut s = ((vgetq_lane_f64::<0>(acc01) + vgetq_lane_f64::<1>(acc01))
                + (vgetq_lane_f64::<0>(acc23) + vgetq_lane_f64::<1>(acc23)))
                + ((vgetq_lane_f64::<0>(acc45) + vgetq_lane_f64::<1>(acc45))
                    + (vgetq_lane_f64::<0>(acc67) + vgetq_lane_f64::<1>(acc67)));
            for (x, y) in a[main..].iter().zip(&b[main..]) {
                s += x * y;
            }
            s
        }
    }

    /// [`crate::kernels::scalar::dense_axpy`], NEON: four 2-wide
    /// `vmulq` + `vaddq` per 8-block (no FMA), scalar tail.
    pub fn dense_axpy(coef: f64, a: &[f64], out: &mut [f64]) {
        debug_assert_eq!(a.len(), out.len());
        let n = a.len();
        let main = n / 8 * 8;
        // SAFETY: all loads/stores stay inside the `main` prefix; NEON
        // is in the aarch64 target baseline.
        unsafe {
            let c = vdupq_n_f64(coef);
            let pa = a.as_ptr();
            let po = out.as_mut_ptr();
            let mut k = 0usize;
            while k < main {
                vst1q_f64(
                    po.add(k),
                    vaddq_f64(vld1q_f64(po.add(k)), vmulq_f64(c, vld1q_f64(pa.add(k)))),
                );
                vst1q_f64(
                    po.add(k + 2),
                    vaddq_f64(vld1q_f64(po.add(k + 2)), vmulq_f64(c, vld1q_f64(pa.add(k + 2)))),
                );
                vst1q_f64(
                    po.add(k + 4),
                    vaddq_f64(vld1q_f64(po.add(k + 4)), vmulq_f64(c, vld1q_f64(pa.add(k + 4)))),
                );
                vst1q_f64(
                    po.add(k + 6),
                    vaddq_f64(vld1q_f64(po.add(k + 6)), vmulq_f64(c, vld1q_f64(pa.add(k + 6)))),
                );
                k += 8;
            }
        }
        for (o, &v) in out[main..].iter_mut().zip(a[main..].iter()) {
            *o += coef * v;
        }
    }
}
