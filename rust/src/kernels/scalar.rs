//! The scalar reference kernels — the bit-exactness ground truth.
//!
//! Every function here commits to the documented accumulation order of
//! the original fused scalar kernels (see the module docs of
//! [`crate::kernels`]). The SIMD backends in [`super::simd`] must
//! reproduce these results bit-for-bit; the property suite
//! (`rust/tests/prop_kernels.rs`) compares the dispatching public kernels
//! against this module on adversarial shapes.
//!
//! These functions skip the per-call length/bounds validation the public
//! dispatchers perform (they carry `debug_assert`s only) — call them
//! through [`crate::kernels`] unless you are a test or bench that has
//! already validated its inputs.

/// 8-lane blocked dense dot product. `chunks_exact(8)` gives LLVM a
/// fixed-width body it fully vectorizes without `-ffast-math`-style
/// reassociation; measured 1.6x over the naive zip/sum and 2.1x over a
/// 4-accumulator manual unroll at the d=54 hot shape, 4.1x at d=1024
/// (EXPERIMENTS.md section Perf, iteration L3-1).
///
/// Reduction order (the bit-exactness contract): 8 independent lane
/// accumulators over the `len / 8 * 8` prefix, combined as
/// `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))`, then the remainder folded in
/// left to right.
#[inline]
pub fn dense_dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 8];
    let ca = a.chunks_exact(8);
    let cb = b.chunks_exact(8);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        for k in 0..8 {
            acc[k] += xa[k] * xb[k];
        }
    }
    let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3]))
        + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for (x, y) in ra.iter().zip(rb) {
        s += x * y;
    }
    s
}

/// `out += coef * a`, blocked like [`dense_dot`] (iteration L3-2: +24% on
/// the d=54 axpy, neutral at d >= 256 where it is memory-bound). Each
/// element update is independent, so the blocking never changes bits.
#[inline]
pub fn dense_axpy(coef: f64, a: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), out.len());
    let ca = a.chunks_exact(8);
    let ra = ca.remainder();
    let co = out.chunks_exact_mut(8);
    for (xo, xa) in co.zip(ca) {
        for k in 0..8 {
            xo[k] += coef * xa[k];
        }
    }
    let tail = out.len() - ra.len();
    for (o, &v) in out[tail..].iter_mut().zip(ra.iter()) {
        *o += coef * v;
    }
}

/// Sparse gather-dot: `sum_k values[k] * w[indices[k]]`, unrolled by 4.
///
/// Reduction order: a single accumulator, strictly left to right — the
/// unroll computes four products ahead (independent rounded ops) but
/// chains the adds sequentially, so the result is bit-identical to the
/// naive `for (i, v) in indices.zip(values) { s += v * w[i] }` loop.
///
/// # Safety
/// Every `indices[k] as usize` must be `< w.len()`. [`crate::data::CsrMatrix`]
/// guarantees this for its rows against any `w` of length `>= cols`.
#[inline]
pub unsafe fn sparse_dot_unchecked(indices: &[u32], values: &[f64], w: &[f64]) -> f64 {
    debug_assert_eq!(indices.len(), values.len());
    debug_assert!(indices.iter().all(|&i| (i as usize) < w.len()));
    let n = indices.len();
    let mut s = 0.0f64;
    let mut k = 0usize;
    while k + 4 <= n {
        let p0 = *values.get_unchecked(k)
            * *w.get_unchecked(*indices.get_unchecked(k) as usize);
        let p1 = *values.get_unchecked(k + 1)
            * *w.get_unchecked(*indices.get_unchecked(k + 1) as usize);
        let p2 = *values.get_unchecked(k + 2)
            * *w.get_unchecked(*indices.get_unchecked(k + 2) as usize);
        let p3 = *values.get_unchecked(k + 3)
            * *w.get_unchecked(*indices.get_unchecked(k + 3) as usize);
        // strictly sequential adds: never reassociated
        s += p0;
        s += p1;
        s += p2;
        s += p3;
        k += 4;
    }
    while k < n {
        s += *values.get_unchecked(k)
            * *w.get_unchecked(*indices.get_unchecked(k) as usize);
        k += 1;
    }
    s
}

/// Sparse scatter-axpy: `out[indices[k]] += coef * values[k]`, unrolled
/// by 4. Updates run strictly left to right (a read-modify-write per
/// element), so rows with repeated indices still fold in the naive order
/// and the result is bit-identical to the scalar loop.
///
/// # Safety
/// Every `indices[k] as usize` must be `< out.len()` (see
/// [`sparse_dot_unchecked`]).
#[inline]
pub unsafe fn sparse_axpy_unchecked(indices: &[u32], values: &[f64], coef: f64, out: &mut [f64]) {
    debug_assert_eq!(indices.len(), values.len());
    debug_assert!(indices.iter().all(|&i| (i as usize) < out.len()));
    let n = indices.len();
    let mut k = 0usize;
    while k + 4 <= n {
        *out.get_unchecked_mut(*indices.get_unchecked(k) as usize) +=
            coef * *values.get_unchecked(k);
        *out.get_unchecked_mut(*indices.get_unchecked(k + 1) as usize) +=
            coef * *values.get_unchecked(k + 1);
        *out.get_unchecked_mut(*indices.get_unchecked(k + 2) as usize) +=
            coef * *values.get_unchecked(k + 2);
        *out.get_unchecked_mut(*indices.get_unchecked(k + 3) as usize) +=
            coef * *values.get_unchecked(k + 3);
        k += 4;
    }
    while k < n {
        *out.get_unchecked_mut(*indices.get_unchecked(k) as usize) +=
            coef * *values.get_unchecked(k);
        k += 1;
    }
}

/// nnz-aware squared norm of a sparse row: `sum_k values[k]^2`, single
/// accumulator left to right (bit-identical to `values.iter().map(|v| v *
/// v).sum()` — iterator `sum` folds sequentially from 0.0).
#[inline]
pub fn sparse_norm_sq(values: &[f64]) -> f64 {
    let mut s = 0.0f64;
    let mut k = 0usize;
    let n = values.len();
    while k + 4 <= n {
        let p0 = values[k] * values[k];
        let p1 = values[k + 1] * values[k + 1];
        let p2 = values[k + 2] * values[k + 2];
        let p3 = values[k + 3] * values[k + 3];
        s += p0;
        s += p1;
        s += p2;
        s += p3;
        k += 4;
    }
    while k < n {
        s += values[k] * values[k];
        k += 1;
    }
    s
}
