//! L1/L2 perf: the AOT Pallas kernel through the PJRT engine.
//!
//! interpret=True on CPU is a correctness vehicle, not a TPU proxy, so
//! these numbers characterize the *structure*: per-step cost vs block
//! shape (is the while-loop body O(d) or accidentally O(n_k·d)?), call
//! overhead, and the H-chunking path. EXPERIMENTS.md §Perf reads the
//! TPU roofline estimate off the BlockSpec instead.
//!
//! ```bash
//! make artifacts && cargo bench --bench pjrt_kernel
//! ```

use cocoa::data::cov_like;
use cocoa::runtime::Engine;
use cocoa::util::bench::time_once;
use cocoa::util::Rng;

fn main() {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.tsv").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        return;
    }
    let engine = Engine::start(dir).unwrap();
    let handle = engine.handle();

    // register both shapes the manifest carries
    for (id, n_k, d) in [(0usize, 128usize, 16usize), (1, 25_000, 54)] {
        let data = cov_like(n_k, d, 0.1, 7 + id as u64);
        let mut x = Vec::with_capacity(n_k * d);
        for i in 0..n_k {
            for v in data.features.row_dense(i) {
                x.push(v as f32);
            }
        }
        let y: Vec<f32> = data.labels.iter().map(|&v| v as f32).collect();
        let norms: Vec<f32> = (0..n_k).map(|i| data.norm_sq(i) as f32).collect();
        handle.register_block(id, x, y, norms, n_k, d).unwrap();
    }

    let mut rng = Rng::seed_from_u64(9);
    let mut run = |id: usize, n_k: usize, d: usize, h: usize, label: &str| {
        let idx: Vec<i32> = (0..h).map(|_| rng.gen_range(n_k) as i32).collect();
        let (out, secs) = time_once(label, || {
            handle
                .local_sdca(id, "hinge", vec![0.0; n_k], vec![0.0; d], idx.clone(), 1.0, 1.0)
                .unwrap()
        });
        println!(
            "    engine compute {:.3} ms -> {:.0} ns/step (H={h})",
            out.compute_s * 1e3,
            out.compute_s * 1e9 / h as f64
        );
        let _ = secs;
    };

    println!("== PJRT local_sdca structural costs ==");
    // call overhead: H = 1
    run(0, 128, 16, 1, "128x16  H=1 (call overhead)");
    run(0, 128, 16, 256, "128x16  H=256 (full capacity)");
    // chunking: H = 3 * cap
    run(0, 128, 16, 768, "128x16  H=768 (3 chunks)");
    // the e2e shape: per-step cost must be ~independent of n_k
    run(1, 25_000, 54, 1_000, "25000x54 H=1000");
    run(1, 25_000, 54, 25_000, "25000x54 H=25000 (full pass)");

    println!("\nIf ns/step at 25000x54 is within ~4x of 128x16, the loop body");
    println!("is O(d) as designed (no hidden O(n_k) per-iteration copies).");
}
