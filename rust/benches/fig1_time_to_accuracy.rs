//! End-to-end bench behind Figures 1 and 2: time (simulated + wall) and
//! communicated vectors to .001-accurate primal suboptimality for each
//! Section-6 algorithm, on the smoke-scale versions of all three dataset
//! regimes.
//!
//! ```bash
//! cargo bench --bench fig1_time_to_accuracy
//! ```
//!
//! (The paper-scale run is `cocoa repro fig1`; this bench keeps the same
//! structure at a size cargo-bench can run on every invocation.)

use cocoa::experiments::{self, figures, Profile};
use cocoa::util::bench::time_once;

fn main() {
    let results_dir = "results/bench";
    let profile = Profile::Smoke;
    let rounds = 200;
    println!("== fig1/fig2 bench: time & communication to .001 suboptimality ==");
    for ds in experiments::datasets(profile) {
        let name = ds.name;
        let (best, wall) = time_once(&format!("sweep {name} (K={})", ds.k), || {
            figures::fig1_fig2_dataset(&ds, profile, rounds, 1e-3, results_dir).unwrap()
        });
        println!(
            "{:<14} {:>8} {:>16} {:>18} {:>14}",
            "algorithm", "best H", "t(.001) sim s", "vectors(.001)", "final subopt"
        );
        for b in &best {
            println!(
                "{:<14} {:>8} {:>16} {:>18} {:>14.2e}",
                b.algorithm,
                b.h,
                b.time_to_target.map(|t| format!("{t:.3}")).unwrap_or("-".into()),
                b.vectors_to_target.map(|v| v.to_string()).unwrap_or("-".into()),
                b.final_subopt,
            );
        }
        let h = figures::headline(&best, name);
        match h.speedup {
            Some(s) => println!(
                "headline[{name}]: cocoa {:.1}x faster than {} (paper: ~25x)  [bench wall {wall:.1}s]\n",
                s,
                h.best_other.unwrap().0
            ),
            None => println!("headline[{name}]: baseline never reached target\n"),
        }
    }
}
