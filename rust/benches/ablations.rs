//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!  A. inner sampling scheme — i.i.d.-with-replacement (Procedure B, what
//!     the theory assumes) vs random-permutation passes (LibLinear-style);
//!  B. partition strategy — contiguous vs random assignment, and its
//!     effect on Lemma 3's sigma_min and on measured convergence;
//!  C. aggregation — CoCoA averaging (beta_K = 1) vs the CoCoA+ extension
//!     (Aggregation::Add: beta_K = K with sigma' = K scaled subproblems)
//!     across K.
//!
//! ```bash
//! cargo bench --bench ablations
//! ```

use cocoa::data::cov_like;
use cocoa::prelude::*;
use cocoa::theory;
use cocoa::util::bench::time_once;

fn gap_after(
    data: &Dataset,
    part: Partition,
    algo: &mut dyn Algorithm,
    solver: SolverKind,
    rounds: u64,
    seed: u64,
) -> f64 {
    let mut session = Trainer::on(data)
        .partition(part)
        .loss(LossKind::Hinge)
        .lambda(1.0 / data.n() as f64)
        .solver(solver)
        .network(NetworkModel::free())
        .seed(seed)
        .label("ablate")
        .build()
        .unwrap();
    let tr = session
        .run(algo, DriverSpec::new(MaxRounds::new(rounds)).eval_every(rounds))
        .unwrap();
    session.shutdown();
    tr.rows.last().unwrap().gap
}

fn main() {
    let data = cov_like(4000, 54, 0.1, 101);
    let k = 4;
    let h = data.n() / k;
    let part = Partition::new(PartitionStrategy::Contiguous, data.n(), k, 0);

    // --- A: sampling scheme ---
    println!("== ablation A: inner sampling scheme (cov 4000x54, K=4, 10 rounds) ==");
    for (name, solver) in [
        ("with_replacement", SolverKind::Sdca),
        ("permutation", SolverKind::SdcaPerm),
    ] {
        let ((), secs) = time_once(&format!("sampling={name}"), || {
            let gap = gap_after(&data, part.clone(), &mut Cocoa::new(h), solver, 10, 7);
            println!("  sampling={name:<18} final gap {gap:.3e}");
        });
        let _ = secs;
    }

    // --- B: partition strategy vs sigma_min and convergence ---
    println!("\n== ablation B: partition strategy (Lemma 3 sigma_min + convergence) ==");
    println!(
        "{:<14} {:>12} {:>14}",
        "strategy", "sigma_min", "gap @10 rounds"
    );
    for strategy in [
        PartitionStrategy::Contiguous,
        PartitionStrategy::RoundRobin,
        PartitionStrategy::Random,
    ] {
        let p = Partition::new(strategy, data.n(), k, 3);
        let sigma = theory::sigma_min_estimate(&data, &p, 60, 5);
        let gap = gap_after(&data, p, &mut Cocoa::new(h), SolverKind::Sdca, 10, 9);
        println!("{:<14} {:>12.3} {:>14.3e}", strategy.name(), sigma, gap);
    }

    // --- C: aggregation across K ---
    println!("\n== ablation C: averaging (CoCoA) vs sigma'-scaled adding (CoCoA+) ==");
    println!("{:<4} {:>16} {:>16}", "K", "cocoa gap@8", "cocoa+ gap@8");
    for k in [2usize, 4, 8, 16] {
        let p = Partition::new(PartitionStrategy::Contiguous, data.n(), k, 0);
        let h = data.n() / k;
        let plain = gap_after(&data, p.clone(), &mut Cocoa::new(h), SolverKind::Sdca, 8, 11);
        let plus = gap_after(&data, p, &mut Cocoa::adding(h), SolverKind::Sdca, 8, 11);
        println!("{:<4} {:>16.3e} {:>16.3e}", k, plain, plus);
    }
    println!("\nExpected shape: permutation ~ with-replacement (slightly better);");
    println!("partition strategy barely moves sigma_min on i.i.d.-ish data;");
    println!("CoCoA+ pulls ahead of averaging as K grows (its 1/K dilution bites).");
}
