//! Bench behind Figure 3 (effect of H on CoCoA) and Figure 4 (beta
//! scaling): the communication/computation trade-off curve on the cov
//! regime, plus the beta sensitivity table.
//!
//! Also measures the warm-start win: `figures::fig3` reuses ONE session's
//! worker threads across the whole H sweep (`Session::reset`), versus the
//! old rebuild-the-cluster-per-H pattern, timed side by side below.
//!
//! ```bash
//! cargo bench --bench fig3_h_tradeoff
//! ```

use cocoa::algorithms::Cocoa;
use cocoa::config::Backend;
use cocoa::driver::MaxRounds;
use cocoa::experiments::{self, cached_optimum, figures, make_session, Profile};
use cocoa::loss::LossKind;
use cocoa::transport::TransportKind;
use cocoa::util::bench::time_once;

fn main() {
    let results_dir = "results/bench";
    let profile = Profile::Smoke;
    let ds = &experiments::datasets(profile)[0]; // cov, K = 4 as in the paper

    // prime the P* cache so neither timed sweep pays the optimum solve
    let p_star = cached_optimum(ds, LossKind::Hinge, results_dir).unwrap();

    // --- Figure 3: H sweep (one warm-started session for the whole grid) ---
    let (runs, warm_secs) = time_once("fig3 H sweep (cov, warm-started session)", || {
        figures::fig3(ds, profile, 120, results_dir).unwrap()
    });
    println!("\nFigure 3: effect of H on CoCoA ({} K={})", ds.name, ds.k);
    println!(
        "{:>8} {:>10} {:>14} {:>14} {:>16} {:>16}",
        "H", "rounds", "final subopt", "sim time s", "vectors total", "measured bytes"
    );
    for (h, tr) in &runs {
        let last = tr.rows.last().unwrap();
        println!(
            "{:>8} {:>10} {:>14.2e} {:>14.3} {:>16} {:>16}",
            h, last.round, last.primal_subopt, last.sim_time_s, last.vectors, last.bytes_measured
        );
    }

    // --- warm-start ablation: same sweep, rebuilding the cluster per H ---
    // (identical work to figures::fig3 — same P*, same CSV writes — so the
    // only difference timed is reset() vs rebuild)
    let grid: Vec<usize> = runs.iter().map(|(h, _)| *h).collect();
    let ((), cold_secs) = time_once("fig3 H sweep (cold: rebuild per H)", || {
        for &h in &grid {
            let mut session = make_session(
                ds,
                LossKind::Hinge,
                Backend::Native,
                "artifacts",
                19,
                TransportKind::Counted,
            )
            .unwrap();
            session.set_reference_optimum(Some(p_star));
            let trace = session.run(&mut Cocoa::new(h), MaxRounds::new(120)).unwrap();
            trace
                .to_csv(format!("{results_dir}/fig3_cold/cocoa_h{h}.csv"))
                .unwrap();
            session.shutdown();
        }
    });
    println!(
        "\nwarm-start: {} session builds avoided — warm {warm_secs:.2}s vs cold {cold_secs:.2}s \
         ({:.2}x, spawn/partition/registration amortized; trajectories identical by reset contract)",
        grid.len().saturating_sub(1),
        cold_secs / warm_secs.max(1e-9),
    );

    // --- Figure 4: beta scaling at two batch sizes ---
    let n_k = ds.data.n() / ds.k;
    for h in [n_k, 100.min(n_k)] {
        let (cells, _) = time_once(&format!("fig4 beta sweep (H={h})"), || {
            figures::fig4(ds, h, 120, 1e-3, results_dir).unwrap()
        });
        println!("\nFigure 4: beta scaling on {} at H={h}", ds.name);
        println!(
            "{:<14} {:>10} {:>16} {:>14}",
            "algorithm", "beta", "t(.001) sim s", "final subopt"
        );
        for c in &cells {
            println!(
                "{:<14} {:>10.1} {:>16} {:>14.2e}",
                c.algorithm,
                c.beta,
                c.time_to_target.map(|t| format!("{t:.3}")).unwrap_or("-".into()),
                c.final_subopt
            );
        }
    }
}
