//! Bench behind Figure 3 (effect of H on CoCoA) and Figure 4 (beta
//! scaling): the communication/computation trade-off curve on the cov
//! regime, plus the beta sensitivity table.
//!
//! ```bash
//! cargo bench --bench fig3_h_tradeoff
//! ```

use cocoa::experiments::{self, figures, Profile};
use cocoa::util::bench::time_once;

fn main() {
    let results_dir = "results/bench";
    let profile = Profile::Smoke;
    let ds = &experiments::datasets(profile)[0]; // cov, K = 4 as in the paper

    // --- Figure 3: H sweep ---
    let (runs, _) = time_once("fig3 H sweep (cov)", || {
        figures::fig3(ds, profile, 120, results_dir).unwrap()
    });
    println!("\nFigure 3: effect of H on CoCoA ({} K={})", ds.name, ds.k);
    println!(
        "{:>8} {:>10} {:>14} {:>14} {:>16}",
        "H", "rounds", "final subopt", "sim time s", "vectors total"
    );
    for (h, tr) in &runs {
        let last = tr.rows.last().unwrap();
        println!(
            "{:>8} {:>10} {:>14.2e} {:>14.3} {:>16}",
            h, last.round, last.primal_subopt, last.sim_time_s, last.vectors
        );
    }

    // --- Figure 4: beta scaling at two batch sizes ---
    let n_k = ds.data.n() / ds.k;
    for h in [n_k, 100.min(n_k)] {
        let (cells, _) = time_once(&format!("fig4 beta sweep (H={h})"), || {
            figures::fig4(ds, h, 120, 1e-3, results_dir).unwrap()
        });
        println!("\nFigure 4: beta scaling on {} at H={h}", ds.name);
        println!(
            "{:<14} {:>10} {:>16} {:>14}",
            "algorithm", "beta", "t(.001) sim s", "final subopt"
        );
        for c in &cells {
            println!(
                "{:<14} {:>10.1} {:>16} {:>14.2e}",
                c.algorithm,
                c.beta,
                c.time_to_target.map(|t| format!("{t:.3}")).unwrap_or("-".into()),
                c.final_subopt
            );
        }
    }
}
