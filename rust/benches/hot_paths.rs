//! Micro-benchmarks of the hot paths (L3 perf deliverable, EXPERIMENTS.md
//! section Perf): the dense/sparse row kernels, the SDCA inner step, a full
//! local epoch, the leader reduce, and the evaluation pass.
//!
//! ```bash
//! cargo bench --bench hot_paths
//! ```

use cocoa::data::{cov_like, rcv1_like, Features};
use cocoa::kernels;
use cocoa::loss::{Hinge, Loss};
use cocoa::objective;
use cocoa::solvers::{Block, LocalDualMethod, LocalSdca, LocalUpdate, Sampling};
use cocoa::util::bench::{bench, black_box};
use cocoa::util::Rng;

/// The pre-kernels inner loop, reproduced verbatim: bounds-checked naive
/// gather/scatter through per-element indexing, the curvature division
/// re-run every step, and the full-d delta extraction. Benched against
/// `LocalSdca::local_update` below to measure the sparse hot-path speedup
/// this refactor bought (the two produce bit-identical results — pinned
/// by rust/tests/prop_kernels.rs).
fn pre_pr_sparse_local_update(
    block: &Block,
    loss: &dyn Loss,
    alpha: &[f64],
    w: &[f64],
    h: usize,
    rng: &mut Rng,
) -> LocalUpdate {
    let m = match &block.data.features {
        Features::Sparse(m) => m,
        Features::Dense(_) => unreachable!("sparse baseline"),
    };
    let n_k = block.n_k();
    let mut dalpha = vec![0.0; n_k];
    let mut w_local = w.to_vec();
    let inv_lambda_n = 1.0 / block.lambda_n;
    for _ in 0..h {
        let i = rng.gen_range(n_k);
        let (idx, val) = m.row_view(i);
        let mut q = 0.0;
        for (c, v) in idx.iter().zip(val) {
            q += v * w_local[*c as usize];
        }
        let s = block.data.norm_sq(i) / block.lambda_n;
        let delta = loss.coord_delta(q, block.data.labels[i], alpha[i] + dalpha[i], s);
        if delta != 0.0 {
            dalpha[i] += delta;
            let coef = delta * inv_lambda_n;
            for (c, v) in idx.iter().zip(val) {
                w_local[*c as usize] += coef * v;
            }
        }
    }
    let dw = w_local.iter().zip(w.iter()).map(|(wl, w0)| wl - w0).collect();
    LocalUpdate { dalpha, dw, steps: h as u64, offloaded_s: 0.0 }
}

fn main() {
    println!("== hot paths (native backend) ==");

    // --- row kernels, the innermost ops ---
    let dense = cov_like(4096, 54, 0.1, 1);
    let wide = cov_like(512, 1024, 0.1, 2);
    let sparse = rcv1_like(4096, 10_000, 12, 0.1, 3);
    let w54: Vec<f64> = (0..54).map(|i| (i as f64).sin()).collect();
    let w1024: Vec<f64> = (0..1024).map(|i| (i as f64).sin()).collect();
    let w10k: Vec<f64> = (0..10_000).map(|i| (i as f64).sin()).collect();

    let mut i = 0usize;
    bench("row_dot dense d=54", 30, 2.0, || {
        i = (i + 1) & 4095;
        black_box(dense.features.row_dot(i, &w54));
    });
    bench("row_dot dense d=1024", 30, 2.0, || {
        i = (i + 1) & 511;
        black_box(wide.features.row_dot(i, &w1024));
    });
    bench("row_dot csr ~12nnz of d=10k", 30, 2.0, || {
        i = (i + 1) & 4095;
        black_box(sparse.features.row_dot(i, &w10k));
    });

    let mut out54 = vec![0.0; 54];
    bench("axpy dense d=54", 30, 2.0, || {
        i = (i + 1) & 4095;
        dense.features.add_row_scaled(i, 1e-9, &mut out54);
    });

    // --- one SDCA coordinate step (dot + solve + axpy) ---
    let block = Block::new(cov_like(4096, 54, 0.1, 4), 1e-5 * 4096.0);
    let mut w_local = vec![0.0; 54];
    let mut alpha = vec![0.0; 4096];
    let mut rng = Rng::seed_from_u64(5);
    bench("sdca inner step dense d=54", 30, 2.0, || {
        let i = rng.gen_range(4096);
        let q = block.data.features.row_dot(i, &w_local);
        let delta = Hinge.coord_delta(q, block.data.labels[i], alpha[i], block.curvature(i));
        alpha[i] += delta;
        block
            .data
            .features
            .add_row_scaled(i, delta / block.lambda_n, &mut w_local);
    });

    // --- a full local epoch (the per-round unit of work) ---
    let solver = LocalSdca::new(Sampling::WithReplacement);
    let alpha0 = vec![0.0; 4096];
    let w0 = vec![0.0; 54];
    let mut rng2 = Rng::seed_from_u64(6);
    bench("local epoch H=4096 dense 4096x54", 15, 30.0, || {
        black_box(solver.local_update(&block, &Hinge, &alpha0, &w0, 4096, &mut rng2));
    });

    let sparse_block =
        Block::new(rcv1_like(4096, 10_000, 12, 0.1, 7), 1e-4 * 4096.0);
    let alpha_s = vec![0.0; 4096];
    let w_s = vec![0.0; 10_000];
    let mut rng3 = Rng::seed_from_u64(8);
    let fused = bench("local epoch H=4096 csr 4096x10k (fused kernels)", 15, 30.0, || {
        black_box(solver.local_update(&sparse_block, &Hinge, &alpha_s, &w_s, 4096, &mut rng3));
    });
    let mut rng3b = Rng::seed_from_u64(8);
    let naive = bench("local epoch H=4096 csr 4096x10k (pre-PR baseline)", 15, 30.0, || {
        black_box(pre_pr_sparse_local_update(
            &sparse_block, &Hinge, &alpha_s, &w_s, 4096, &mut rng3b,
        ));
    });
    println!(
        "  sparse inner-loop speedup vs pre-PR baseline: {:.2}x \
         ({:.0} -> {:.0} steps/ms)",
        naive.median_ns / fused.median_ns,
        4096.0 / (naive.median_ns / 1e6),
        4096.0 / (fused.median_ns / 1e6),
    );

    // --- the sparse row kernels head-to-head (gather dot) ---
    {
        let (idx_bench, val_bench) = match &sparse_block.data.features {
            Features::Sparse(m) => {
                // pick a mid-sized row so the kernel sees a typical nnz
                let mut best = 0;
                for i in 0..4096 {
                    if m.row_view(i).0.len() >= 12 {
                        best = i;
                        break;
                    }
                }
                m.row_view(best)
            }
            Features::Dense(_) => unreachable!(),
        };
        let w10k_ref = &w10k;
        bench("sparse_dot kernel (unchecked, unrolled)", 30, 1.0, || {
            // the path CsrMatrix::row_dot takes after its one length check
            black_box(unsafe {
                kernels::sparse_dot_unchecked(idx_bench, val_bench, w10k_ref)
            });
        });
        bench("sparse_dot naive (bounds-checked)", 30, 1.0, || {
            let mut s = 0.0;
            for (c, v) in idx_bench.iter().zip(val_bench) {
                s += v * w10k_ref[*c as usize];
            }
            black_box(s);
        });
    }

    // --- leader-side reduce (w += scale * sum dw) ---
    let dws: Vec<Vec<f64>> = (0..8).map(|s| {
        let mut r = Rng::seed_from_u64(s);
        (0..54).map(|_| r.gen_f64()).collect()
    }).collect();
    let mut w_leader = vec![0.0; 54];
    bench("leader reduce K=8 d=54", 30, 1.0, || {
        for dw in &dws {
            for (a, b) in w_leader.iter_mut().zip(dw) {
                *a += 0.125 * b;
            }
        }
        black_box(&w_leader);
    });

    // --- evaluation pass (per-round instrumentation cost) ---
    bench("block objective eval 4096x54", 15, 10.0, || {
        black_box(objective::block_loss_sum(&block.data, &w0, &Hinge));
        black_box(objective::block_conj_sum(&block.data, &alpha0, &Hinge));
    });

    // --- regularizer prox-step kernel (the leader's per-commit map) ---
    {
        use cocoa::regularizers::{Regularizer, RegularizerKind};
        let l1 = RegularizerKind::L1 { epsilon: 0.5 }.build();
        let l2 = RegularizerKind::L2.build();
        let v: Vec<f64> = (0..10_000).map(|i| 3.0 * (i as f64 * 0.37).sin()).collect();
        let mut w_out = vec![0.0f64; 10_000];
        bench("prox map dense d=10k (l1 soft threshold)", 30, 1.0, || {
            l1.prox_into(&v, &mut w_out);
            black_box(&w_out);
        });
        bench("prox map dense d=10k (l2 identity)", 30, 1.0, || {
            l2.prox_into(&v, &mut w_out);
            black_box(&w_out);
        });
        // sparse-column variant: after a sparse-data round only the
        // touched coordinates of v moved, so the map only needs to revisit
        // those — the L1 inner-loop shape future regressions would hit
        let touched: Vec<usize> = (0..10_000).step_by(83).collect(); // ~120 cols
        bench("prox map sparse ~120 touched of d=10k", 30, 1.0, || {
            for &j in &touched {
                w_out[j] = l1.prox_coord(v[j]);
            }
            black_box(&w_out);
        });
    }

    // --- transport wire format: sparse delta-encoding of RoundReply.dw ---
    {
        use cocoa::transport::{decode_dw, encode_dw};
        let dense_dw: Vec<f64> = (0..54).map(|i| (i as f64).cos()).collect();
        let mut sparse_dw = vec![0.0f64; 10_000];
        for i in (0..10_000).step_by(800) {
            sparse_dw[i] = (i as f64 + 1.0).sin(); // ~13 nnz, rcv1-like reply
        }
        bench("encode_dw dense d=54", 30, 1.0, || {
            black_box(encode_dw(&dense_dw));
        });
        bench("encode_dw sparse d=10k nnz~13", 30, 1.0, || {
            black_box(encode_dw(&sparse_dw));
        });
        let enc_sparse = encode_dw(&sparse_dw);
        let enc_dense = encode_dw(&dense_dw);
        bench("decode_dw sparse d=10k", 30, 1.0, || {
            black_box(decode_dw(&enc_sparse));
        });
        println!(
            "  dw wire sizes: dense d=54 -> {} B; sparse d=10k -> {} B (vs {} B dense)",
            enc_dense.len(),
            enc_sparse.len(),
            1 + 4 + 8 * 10_000,
        );
    }

    // --- coordinator round overhead (dispatch + gather + commit, H=0) ---
    {
        use cocoa::coordinator::LocalWork;
        use cocoa::loss::LossKind;
        use cocoa::netsim::NetworkModel;
        use cocoa::transport::TransportKind;
        use cocoa::Trainer;
        let data = cov_like(256, 54, 0.1, 9);
        let mut session = Trainer::on(&data)
            .workers(4)
            .loss(LossKind::Hinge)
            .lambda(0.01)
            .network(NetworkModel::free())
            .seed(10)
            .build()
            .unwrap();
        bench("coordinator round overhead K=4 (H=0)", 15, 5.0, || {
            let replies = session.dispatch(|_| LocalWork::DualRound { h: 0 }).unwrap();
            session.commit(&replies, 0.25).unwrap();
        });
        // warm-start vs rebuild: what Session::reset saves per sweep point.
        // reset() is fire-and-forget, so follow it with an H=0 round as a
        // barrier — the delta vs the round-overhead bench above isolates
        // the workers' actual reset work.
        bench("session reset + round barrier (warm-start)", 15, 2.0, || {
            session.reset().unwrap();
            let replies = session.dispatch(|_| LocalWork::DualRound { h: 0 }).unwrap();
            session.commit(&replies, 0.25).unwrap();
        });
        session.shutdown();
        // same round loop on the byte-exact transport: the delta vs the
        // inproc round-overhead bench above is the cost of counting
        let mut counted = Trainer::on(&data)
            .workers(4)
            .loss(LossKind::Hinge)
            .lambda(0.01)
            .network(NetworkModel::free())
            .transport(TransportKind::Counted)
            .seed(10)
            .build()
            .unwrap();
        bench("coordinator round overhead K=4 (counted)", 15, 5.0, || {
            let replies = counted.dispatch(|_| LocalWork::DualRound { h: 0 }).unwrap();
            counted.commit(&replies, 0.25).unwrap();
        });
        println!(
            "  counted after bench: {} B measured over {} rounds",
            counted.stats().bytes_measured,
            counted.stats().rounds,
        );
        counted.shutdown();
        bench("session build + shutdown (cold start)", 15, 5.0, || {
            let s = Trainer::on(&data)
                .workers(4)
                .loss(LossKind::Hinge)
                .lambda(0.01)
                .network(NetworkModel::free())
                .seed(10)
                .build()
                .unwrap();
            s.shutdown();
        });
    }

    println!("\nderived: steps/s for the dense d=54 epoch = H / epoch_time.");
}
