#!/usr/bin/env bash
# CI gate for the cocoa crate: build, test, determinism, the serving
# smoke (cocoa serve + cocoa score over UDS), perf smoke, perf
# regression gate (vs benchmarks/BENCH_hotpath.json), the out-of-core
# smoke (shard -> mmap-backed train under an RSS budget), lint.
#
#   ./ci.sh            # everything
#   ./ci.sh --fast     # skip clippy/fmt/doc (tier-1 + determinism + perf smoke)
#
# Tier-1 (the driver's gate) is exactly: cargo build --release && cargo test -q
#
# Scratch comparisons live in a mktemp -d sandbox removed on exit, so runs
# from different checkouts never collide in /tmp (the old fixed-path bug).
# The determinism tests themselves write seed-scoped files under this
# checkout's target/ — like any cargo artifact, one ci.sh run per checkout
# at a time.

set -euo pipefail
cd "$(dirname "$0")"

SCRATCH="$(mktemp -d "${TMPDIR:-/tmp}/cocoa_ci.XXXXXX")"
trap 'rm -rf "$SCRATCH"' EXIT

step() { printf '\n== %s ==\n' "$*"; }

DET_SEED="${CARGO_TEST_SEED:-42}"

# run_determinism_gate <label> <test target> <test name> <trace file>
#
# Runs the named seeded test twice with CARGO_TEST_SEED pinned and diffs
# the trace fingerprint it writes (gap/dual/primal bit patterns, byte
# totals, final-w hash). Any nondeterminism in the transport, the
# reduction order, the kernels, or the byte accounting shows up here.
# The second run's trace is left in place (target/determinism/) so CI can
# upload it as an artifact.
run_determinism_gate() {
    local label="$1" target="$2" name="$3" trace="$4"
    step "seeded determinism: $label (same seed => identical trace)"
    rm -f "$trace"
    CARGO_TEST_SEED="$DET_SEED" cargo test -q --test "$target" "$name"
    cp "$trace" "$SCRATCH/${label}_run1.csv"
    rm -f "$trace"
    CARGO_TEST_SEED="$DET_SEED" cargo test -q --test "$target" "$name"
    diff "$SCRATCH/${label}_run1.csv" "$trace"
    printf 'determinism(%s): two seeded runs produced identical traces\n' "$label"
}

step "cargo build --release"
cargo build --release

step "cargo test -q"
cargo test -q

# All five examples must keep building against the public API (the Driver
# redesign migrated every one of them), and quickstart must actually run:
# it exercises Session::run with composable rules, a manual Driver::step()
# loop, and the CSV/Trace observer sinks end-to-end.
step "cargo build --release --examples"
cargo build --release --examples

step "run quickstart example (driver API end-to-end)"
./target/release/examples/quickstart > "$SCRATCH/quickstart.out"
grep -q "observer run:" "$SCRATCH/quickstart.out"

run_determinism_gate "l2_transport" prop_transport seeded_determinism_artifact \
    "target/determinism/trace_${DET_SEED}.csv"
run_determinism_gate "l1_prox" golden_lasso seeded_determinism_artifact_l1 \
    "target/determinism/trace_l1_${DET_SEED}.csv"
# third gate: the step-wise driver streaming through the JSONL observer
# sink — two seeded runs must produce byte-identical artifacts
run_determinism_gate "driver_jsonl" driver_equivalence seeded_driver_jsonl_artifact \
    "target/determinism/driver_${DET_SEED}.jsonl"

# Multi-process smoke: a real leader + 2 worker processes over a Unix
# socket, sharing one config. Gates the socket transport end-to-end —
# versioned handshake, framed wire traffic, clean shutdown — and the
# gap-target stop proves actual optimization happened across processes.
# The leader also runs with full observability on: --trace-out streams
# round-phase spans as JSONL (left under target/determinism/ so CI
# uploads it), and --metrics serves live Prometheus text that a
# background scraper polls MID-RUN over bash's /dev/tcp — no curl
# needed — asserting a well-formed, non-empty exposition.
step "multi-process smoke (cocoa leader + 2 workers over UDS, live /metrics)"
cat > "$SCRATCH/net_smoke.toml" <<'EOF'
lambda = 0.01

[dataset]
kind = "cov_like"
n = 400
d = 10
seed = 11

[partition]
k = 2

[algorithm]
name = "cocoa"
h = 200

[loss]
kind = "hinge"

[run]
rounds = 400
target_gap = 1e-3

[transport]
kind = "net"
EOF
NET_SOCK="$SCRATCH/net_smoke.sock"
METRICS_PORT=$(( 20000 + ($$ % 20000) ))
SPANS="target/determinism/net_smoke_spans.jsonl"
mkdir -p target/determinism
rm -f "$SPANS"
./target/release/cocoa worker --config "$SCRATCH/net_smoke.toml" \
    --connect "uds:$NET_SOCK" --attempts 40 --backoff-s 0.25 &
W1=$!
./target/release/cocoa worker --config "$SCRATCH/net_smoke.toml" \
    --connect "uds:$NET_SOCK" --attempts 40 --backoff-s 0.25 &
W2=$!
# Mid-run scraper: retry GET /metrics until a body carrying per-slot
# solve analytics lands (present from round 1 on; the endpoint stays up
# until the leader exits, so only startup is raced).
(
    for _ in $(seq 1 400); do
        if { exec 3<>"/dev/tcp/127.0.0.1/$METRICS_PORT"; } 2>/dev/null; then
            printf 'GET /metrics HTTP/1.0\r\n\r\n' >&3
            cat <&3 > "$SCRATCH/metrics_scrape.http"
            exec 3>&- 3<&-
            if grep -q '^cocoa_solve_seconds_count{' "$SCRATCH/metrics_scrape.http"; then
                exit 0
            fi
        fi
        sleep 0.05
    done
    exit 1
) &
SCRAPER=$!
./target/release/cocoa leader --config "$SCRATCH/net_smoke.toml" \
    --listen "uds:$NET_SOCK" --workers 2 --out "$SCRATCH/net_smoke.csv" \
    --trace-out "$SPANS" --metrics "tcp:127.0.0.1:$METRICS_PORT" \
    > "$SCRATCH/net_smoke.out"
wait "$W1" "$W2"   # set -e: nonzero worker exit fails the gate
wait "$SCRAPER"    # the mid-run scrape must have landed a metrics body
grep -q "stop=gap" "$SCRATCH/net_smoke.out"
grep -q "socket: sent" "$SCRATCH/net_smoke.out"
# the captured scrape is a complete, well-formed Prometheus exposition
grep -q 'HTTP/1.0 200 OK' "$SCRATCH/metrics_scrape.http"
grep -q '^cocoa_rounds_total ' "$SCRATCH/metrics_scrape.http"
grep -q '^cocoa_phase_seconds_total{phase="local_solve"}' "$SCRATCH/metrics_scrape.http"
grep -q '^cocoa_solve_imbalance_ratio ' "$SCRATCH/metrics_scrape.http"
# the span stream exists, is non-empty, and carries per-slot solve spans
test -s "$SPANS"
grep -q '"phase": "local_solve"' "$SPANS"
grep -q '"phase": "commit"' "$SPANS"
printf 'net smoke: gap target reached over UDS; /metrics scraped mid-run; spans -> %s\n' "$SPANS"

# Serving smoke: `cocoa serve --model live` trains from a config while
# serving the freshest snapshot over a Unix socket, and `cocoa score`
# hits it from another process — versioned scoring handshake, CSR batch
# on the wire, margins back. The scoring client retries connecting, so
# only server startup is raced; the server lingers after training
# (--serve-s) so the score lands whether training is still running or
# already done. Gates the whole serving path end-to-end: SnapshotSink
# publication, the score server thread, the wire protocol, and the
# LibSVM ingestion on the client side.
step "serving smoke (cocoa serve --model live over UDS + cocoa score)"
SERVE_SOCK="$SCRATCH/serve_smoke.sock"
cat > "$SCRATCH/serve_smoke.toml" <<'EOF'
lambda = 0.01

[dataset]
kind = "cov_like"
n = 400
d = 10
seed = 11

[algorithm]
name = "cocoa"
h = 200

[loss]
kind = "hinge"

[run]
rounds = 400
target_gap = 1e-3
EOF
cat > "$SCRATCH/serve_smoke.svm" <<'EOF'
+1 1:0.5 3:1.25 10:-0.75
-1 2:1.0 7:0.25
+1 1:-0.25 5:2.0 9:0.5
-1 4:0.125 8:-1.5
EOF
./target/release/cocoa serve --model live --config "$SCRATCH/serve_smoke.toml" \
    --listen "uds:$SERVE_SOCK" --serve-s 5 > "$SCRATCH/serve_smoke.out" &
SERVER=$!
./target/release/cocoa score --connect "uds:$SERVE_SOCK" \
    --libsvm "$SCRATCH/serve_smoke.svm" --d-hint 10 \
    --attempts 60 --backoff-s 0.25 > "$SCRATCH/score_smoke.out"
grep -Eq '^scored 4 rows from .*: [0-9]+ correct \(snapshot round [0-9]+, epoch [0-9]+\)$' \
    "$SCRATCH/score_smoke.out"
wait "$SERVER"     # set -e: a nonzero serve exit fails the gate
grep -q "finished: rounds=" "$SCRATCH/serve_smoke.out"
grep -Eq '^predictions served: [1-9][0-9]*$' "$SCRATCH/serve_smoke.out"
printf 'serving smoke: cocoa score answered over UDS against the live model\n'

# Perf smoke: run the tiny-profile workloads (training families plus the
# serve_ scoring family) and validate BENCH_hotpath.json structurally
# (fields present, numbers finite, monotone round times).
step "perf smoke (BENCH_hotpath.json schema gate)"
./target/release/cocoa perf --smoke --seed "$DET_SEED" --out target/BENCH_hotpath.json
./target/release/cocoa perf --validate target/BENCH_hotpath.json

# Perf regression gate: compare the candidate against the checked-in
# per-workload baseline. The baseline is deliberately conservative and
# the tolerance band generous (see benchmarks/README.md) — this catches
# order-of-magnitude regressions (debug build in CI, accidental O(n^2)),
# not runner noise. The delta report is uploaded as a CI artifact.
step "perf regression gate (candidate vs benchmarks/BENCH_hotpath.json)"
./target/release/cocoa perf --validate target/BENCH_hotpath.json \
    --baseline benchmarks/BENCH_hotpath.json --tolerance 0.5 \
    --delta target/BENCH_delta.txt

# The gate must be able to FAIL: validate the candidate against itself at
# tolerance -1 (demands >= 2x its own throughput — impossible), and
# require a nonzero exit. If this ever passes, the gate is not gating.
step "perf gate self-test (tolerance -1 must fail)"
if ./target/release/cocoa perf --validate target/BENCH_hotpath.json \
    --baseline target/BENCH_hotpath.json --tolerance -1 \
    > "$SCRATCH/gate_selftest.out" 2>&1; then
    echo "perf gate self-test FAILED: an impossible tolerance passed" >&2
    cat "$SCRATCH/gate_selftest.out" >&2
    exit 1
fi
printf 'perf gate self-test: impossible tolerance correctly exited nonzero\n'

# Out-of-core smoke: stream a synthetic rcv1-regime dataset to on-disk
# shards (~230 MB, never materialized in memory), then train from the
# mmap-backed shards under a hard peak-RSS budget a couple of times
# smaller than the data. --rss-budget-mb makes `cocoa train` itself exit
# nonzero on violation, so this gates the whole out-of-core promise:
# streaming ingest, checksummed shard open, mmap row views, and the
# residency budget. Kept under target/ooc_smoke (not the mktemp scratch)
# so CI can upload the shard directory as an artifact when the gate fails.
step "out-of-core smoke (cocoa shard -> mmap-backed train under --rss-budget-mb)"
OOC_DIR="target/ooc_smoke"
rm -rf "$OOC_DIR"
mkdir -p "$OOC_DIR"
./target/release/cocoa shard --synthetic rcv1 \
    --n 120000 --d 40000 --nnz 160 --seed "$DET_SEED" \
    --workers 2 --out "$OOC_DIR/shards" 2> "$OOC_DIR/shard.log"
grep -q '^sharded n=120000 d=40000 ' "$OOC_DIR/shard.log"
cat > "$OOC_DIR/ooc_smoke.toml" <<EOF
lambda = 1e-5

[data]
shards = "$OOC_DIR/shards"

[algorithm]
name = "cocoa"
h = 60000

[loss]
kind = "logistic"

[run]
rounds = 2
seed = $DET_SEED
EOF
./target/release/cocoa train --config "$OOC_DIR/ooc_smoke.toml" \
    --out "$OOC_DIR/ooc_smoke.csv" --rss-budget-mb 120 \
    2> "$OOC_DIR/train.log"
# off Linux peak RSS is unreadable and train says "not enforced" — the
# run itself still exercises the full shard path; CI (ubuntu) enforces.
grep -Eq 'within --rss-budget-mb 120|--rss-budget-mb 120 not enforced' \
    "$OOC_DIR/train.log"
test -s "$OOC_DIR/ooc_smoke.csv"
printf 'ooc smoke: trained from mmap shards under the 120 MiB RSS budget\n'

if [[ "${1:-}" != "--fast" ]]; then
    step "cargo doc --no-deps (rustdoc warnings are errors)"
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

    step "cargo clippy -- -D warnings"
    cargo clippy --all-targets -- -D warnings

    step "cargo fmt --check"
    cargo fmt --check
fi

printf '\nci: all green\n'
