#!/usr/bin/env bash
# CI gate for the cocoa crate: build, test, lint, format.
#
#   ./ci.sh            # everything
#   ./ci.sh --fast     # skip clippy/fmt (tier-1 + determinism gate)
#
# Tier-1 (the driver's gate) is exactly: cargo build --release && cargo test -q

set -euo pipefail
cd "$(dirname "$0")"

step() { printf '\n== %s ==\n' "$*"; }

step "cargo build --release"
cargo build --release

step "cargo test -q"
cargo test -q

# Seeded-determinism gate: the prop_transport suite writes a fingerprint of
# a seeded SimNet run (gap/dual/primal bit patterns, byte totals, final-w
# hash) to target/determinism/trace_<seed>.csv. Run it twice with the seed
# pinned and diff — any nondeterminism in the transport, the coordinator's
# reduction order, or the byte accounting shows up here.
step "seeded determinism (same seed => identical trace + byte totals)"
DET_SEED="${CARGO_TEST_SEED:-42}"
DET_FILE="target/determinism/trace_${DET_SEED}.csv"
rm -f "$DET_FILE"
CARGO_TEST_SEED="$DET_SEED" cargo test -q --test prop_transport seeded_determinism_artifact
cp "$DET_FILE" /tmp/cocoa_determinism_run1.csv
rm -f "$DET_FILE"
CARGO_TEST_SEED="$DET_SEED" cargo test -q --test prop_transport seeded_determinism_artifact
diff /tmp/cocoa_determinism_run1.csv "$DET_FILE"
printf 'determinism: two seeded runs produced identical traces\n'

# Same gate for the L1/prox path: the golden_lasso suite writes an L1-run
# fingerprint (counted transport, leader-side prox, sparse broadcast byte
# accounting) — any nondeterminism in the regularizer path diffs here.
step "seeded determinism, L1 prox path"
DET_L1_FILE="target/determinism/trace_l1_${DET_SEED}.csv"
rm -f "$DET_L1_FILE"
CARGO_TEST_SEED="$DET_SEED" cargo test -q --test golden_lasso seeded_determinism_artifact_l1
cp "$DET_L1_FILE" /tmp/cocoa_determinism_l1_run1.csv
rm -f "$DET_L1_FILE"
CARGO_TEST_SEED="$DET_SEED" cargo test -q --test golden_lasso seeded_determinism_artifact_l1
diff /tmp/cocoa_determinism_l1_run1.csv "$DET_L1_FILE"
printf 'determinism: two seeded L1 runs produced identical traces\n'

if [[ "${1:-}" != "--fast" ]]; then
    step "cargo doc --no-deps (rustdoc warnings are errors)"
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

    step "cargo clippy -- -D warnings"
    cargo clippy --all-targets -- -D warnings

    step "cargo fmt --check"
    cargo fmt --check
fi

printf '\nci: all green\n'
