#!/usr/bin/env bash
# CI gate for the cocoa crate: build, test, lint, format.
#
#   ./ci.sh            # everything
#   ./ci.sh --fast     # skip clippy/fmt (tier-1 only)
#
# Tier-1 (the driver's gate) is exactly: cargo build --release && cargo test -q

set -euo pipefail
cd "$(dirname "$0")"

step() { printf '\n== %s ==\n' "$*"; }

step "cargo build --release"
cargo build --release

step "cargo test -q"
cargo test -q

if [[ "${1:-}" != "--fast" ]]; then
    step "cargo clippy -- -D warnings"
    cargo clippy --all-targets -- -D warnings

    step "cargo fmt --check"
    cargo fmt --check
fi

printf '\nci: all green\n'
