//! Quickstart: drive a distributed SVM round by round with the step-wise
//! [`Driver`] API, then let the batch wrapper do the same in one call.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Generates a small cov-regime dataset, builds one [`Session`] (K = 4
//! worker threads over an EC2-like network), and shows the three ways to
//! run it: `Session::run` with composable stopping rules, a manual
//! `Driver::step()` loop where the caller owns the round boundary, and a
//! driver with observers streaming rows to CSV — all on the same
//! warm-started worker threads.

use cocoa::data::cov_like;
use cocoa::prelude::*;

fn main() -> cocoa::Result<()> {
    // 1. data: n = 8000 points in d = 54 (cov regime), K = 4 workers
    let data = cov_like(8_000, 54, 0.1, 42);
    let lambda = 1.0 / data.n() as f64;
    let h = data.n() / 4; // one local pass per round

    // 2. one session: a typed builder, validated at build()
    let mut session = Trainer::on(&data)
        .workers(4)
        .loss(LossKind::Hinge)
        .lambda(lambda)
        .network(NetworkModel::ec2_like())
        .seed(7)
        .label("quickstart")
        .build()?;
    println!("quickstart: n={} d={} K=4 lambda={lambda:.2e} H={h}", data.n(), data.d());

    // 3. batch mode: stop at a duality gap, with a round-cap safety net
    //    (rules compose with .or()/.and(); first listed wins ties)
    let trace = session.run(&mut Cocoa::new(h), GapBelow::new(1e-4).or(MaxRounds::new(20)))?;
    let last = trace.rows.last().unwrap();
    println!(
        "\nbatch run:   gap {:.2e} after {} rounds (stop = {})",
        last.gap, last.round, last.stop
    );

    // 4. step mode: the caller owns the round boundary. step() yields
    //    typed events — inspect every round, adapt, or pause mid-run.
    //    Here: CoCoA+ (the beta_K = K adding regime), same threads.
    session.reset()?;
    let mut plus = Cocoa::adding(h);
    let mut driver = session.drive(&mut plus, GapBelow::new(1e-4).or(MaxRounds::new(20)))?;
    println!("\nstep loop ({}):", driver.meta().algorithm);
    loop {
        match driver.step()? {
            RoundEvent::Evaluated { row } if row.round % 4 == 0 => println!(
                "  round {:>3}  P {:.6}  gap {:.2e}  sim {:.3}s",
                row.round, row.primal, row.gap, row.sim_time_s
            ),
            RoundEvent::Stopped { reason } => {
                println!("  stopped: {reason}");
                break;
            }
            _ => {}
        }
    }
    drop(driver); // releases the session for the next run

    // 5. observers: stream every evaluated row to a CSV file while an
    //    incremental TraceSink builds the same trace the batch mode
    //    returns — telemetry is pluggable, not hardwired into the loop
    session.reset()?;
    let mut csv = CsvSink::create("target/quickstart_trace.csv")?;
    let mut sink = TraceSink::new();
    let mut cocoa = Cocoa::new(h);
    let mut driver = session.drive(&mut cocoa, MaxRounds::new(10))?;
    driver.observe(&mut csv)?;
    driver.observe(&mut sink)?;
    let trace = driver.drain()?;
    drop(driver);
    let streamed = sink.take().expect("observer saw the run");
    assert_eq!(streamed.rows.len(), trace.rows.len());
    println!(
        "\nobserver run: {} rows streamed to target/quickstart_trace.csv",
        streamed.rows.len()
    );

    println!("\nCoCoA closes the duality gap orders of magnitude faster per round —");
    println!("the same updates, applied locally before averaging (Section 3 of the");
    println!("paper); the adding regime (Cocoa::adding) is one constructor away.");
    session.shutdown();
    Ok(())
}
