//! Quickstart: train a distributed SVM with CoCoA in ~30 lines of API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Generates a small cov-regime dataset, builds one [`Session`] (K = 4
//! worker threads over an EC2-like network), runs Algorithm 1 next to the
//! mini-batch SDCA baseline at the same per-round work, then shows the
//! CoCoA+ adding regime — all on the same warm-started worker threads.

use cocoa::data::cov_like;
use cocoa::prelude::*;

fn main() -> cocoa::Result<()> {
    // 1. data: n = 8000 points in d = 54 (cov regime), K = 4 workers
    let data = cov_like(8_000, 54, 0.1, 42);
    let lambda = 1.0 / data.n() as f64;
    let h = data.n() / 4; // one local pass per round

    // 2. one session: a typed builder, validated at build()
    let mut session = Trainer::on(&data)
        .workers(4)
        .loss(LossKind::Hinge)
        .lambda(lambda)
        .network(NetworkModel::ec2_like())
        .seed(7)
        .label("quickstart")
        .build()?;

    println!("quickstart: n={} d={} K=4 lambda={lambda:.2e} H={h}", data.n(), data.d());
    println!(
        "{:<14} {:>6} {:>12} {:>12} {:>14}",
        "algorithm", "round", "P(w)", "gap", "sim time (s)"
    );

    // 3. algorithms are trait objects; reset() warm-starts the same
    //    worker threads between runs
    let mut algos: Vec<Box<dyn Algorithm>> = vec![
        Box::new(Cocoa::new(h)),          // Algorithm 1, beta_K = 1 averaging
        Box::new(MinibatchCd::new(h)),    // frozen-w baseline, same batch
        Box::new(Cocoa::adding(h)),       // CoCoA+: beta_K = K adding
    ];
    for algo in algos.iter_mut() {
        session.reset()?;
        let trace = session.run(algo.as_mut(), Budget::rounds(10))?;
        for row in trace.rows.iter().filter(|r| r.round % 2 == 0) {
            println!(
                "{:<14} {:>6} {:>12.6} {:>12.2e} {:>14.3}",
                algo.name(),
                row.round,
                row.primal,
                row.gap,
                row.sim_time_s
            );
        }
    }
    println!("\nCoCoA closes the duality gap orders of magnitude faster per round —");
    println!("the same updates, applied locally before averaging (Section 3 of the");
    println!("paper); the adding regime (Aggregation::Add) is one constructor away.");
    Ok(())
}
