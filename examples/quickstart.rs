//! Quickstart: train a distributed SVM with CoCoA in ~30 lines of API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Generates a small cov-regime dataset, partitions it over K = 4 worker
//! threads, runs Algorithm 1, and prints the duality-gap trajectory next
//! to the mini-batch SDCA baseline at the same per-round work.

use cocoa::algorithms::{run, Budget};
use cocoa::config::{AlgorithmSpec, Backend};
use cocoa::coordinator::Cluster;
use cocoa::data::{cov_like, Partition, PartitionStrategy};
use cocoa::loss::LossKind;
use cocoa::netsim::NetworkModel;
use cocoa::solvers::SolverKind;

fn main() -> anyhow::Result<()> {
    // 1. data: n = 8000 points in d = 54 (cov regime), K = 4 workers
    let data = cov_like(8_000, 54, 0.1, 42);
    let partition = Partition::new(PartitionStrategy::Contiguous, data.n(), 4, 0);
    let lambda = 1.0 / data.n() as f64;
    let h = data.n() / 4; // one local pass per round

    println!("quickstart: n={} d={} K=4 lambda={lambda:.2e} H={h}", data.n(), data.d());
    println!("{:<14} {:>6} {:>12} {:>12} {:>14}", "algorithm", "round", "P(w)", "gap", "sim time (s)");

    for spec in [
        AlgorithmSpec::Cocoa { h, beta_k: 1.0, solver: SolverKind::Sdca },
        AlgorithmSpec::MinibatchCd { h, beta_b: 1.0 },
    ] {
        // 2. a cluster: leader + 4 worker threads over an EC2-like network
        let mut cluster = Cluster::build(
            &data,
            &partition,
            LossKind::Hinge,
            lambda,
            SolverKind::Sdca,
            Backend::Native,
            "artifacts",
            NetworkModel::ec2_like(),
            7,
        )?;
        // 3. run 10 outer rounds (Algorithm 1), evaluating every round
        let trace = run(&mut cluster, &spec, Budget::rounds(10), 1, None, "quickstart")?;
        cluster.shutdown();
        for row in trace.rows.iter().filter(|r| r.round % 2 == 0) {
            println!(
                "{:<14} {:>6} {:>12.6} {:>12.2e} {:>14.3}",
                spec.name(),
                row.round,
                row.primal,
                row.gap,
                row.sim_time_s
            );
        }
    }
    println!("\nCoCoA closes the duality gap orders of magnitude faster per round —");
    println!("the same updates, applied locally before averaging (Section 3 of the paper).");
    Ok(())
}
