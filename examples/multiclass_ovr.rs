//! Multiclass classification via one-vs-rest — the paper's problem class
//! (1) covers any convex loss of linear predictors; this example shows the
//! framework as a downstream user would apply it to a C-class problem:
//! C independent CoCoA-trained binary SVMs over the same partitioned data.
//!
//! ```bash
//! cargo run --release --example multiclass_ovr
//! ```

use cocoa::algorithms::{run, Budget};
use cocoa::config::{AlgorithmSpec, Backend};
use cocoa::coordinator::Cluster;
use cocoa::data::{Dataset, DenseMatrix, Features, Partition, PartitionStrategy};
use cocoa::loss::LossKind;
use cocoa::netsim::NetworkModel;
use cocoa::solvers::SolverKind;
use cocoa::util::Rng;

const CLASSES: usize = 3;
const N: usize = 6_000;
const D: usize = 20;

/// Gaussian blobs around C well-separated centroids.
fn make_multiclass(n: usize, d: usize, seed: u64) -> (Dataset, Vec<usize>) {
    let mut rng = Rng::seed_from_u64(seed);
    let centroids: Vec<Vec<f64>> = (0..CLASSES)
        .map(|_| (0..d).map(|_| rng.normal() * 2.0).collect())
        .collect();
    let mut rows = Vec::with_capacity(n);
    let mut classes = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % CLASSES;
        let row: Vec<f64> = centroids[c]
            .iter()
            .map(|&m| m + rng.normal())
            .collect();
        rows.push(row);
        classes.push(c);
    }
    let features = Features::Dense(DenseMatrix::from_rows(&rows));
    // placeholder labels; per-class relabeling happens below
    let mut ds = Dataset::new(features, vec![1.0; n]);
    ds.normalize_rows();
    (ds, classes)
}

fn main() -> anyhow::Result<()> {
    let (base, classes) = make_multiclass(N, D, 77);
    let lambda = 1.0 / N as f64;
    let k = 4;
    let partition = Partition::new(PartitionStrategy::RoundRobin, N, k, 0);
    let h = N / k;

    println!("one-vs-rest: {CLASSES} classes, n={N}, d={D}, K={k}");
    let mut models: Vec<Vec<f64>> = Vec::with_capacity(CLASSES);
    for class in 0..CLASSES {
        // relabel: +1 for `class`, -1 for the rest
        let mut ds = base.clone();
        for (label, &c) in ds.labels.iter_mut().zip(&classes) {
            *label = if c == class { 1.0 } else { -1.0 };
        }
        let mut cluster = Cluster::build(
            &ds, &partition, LossKind::Hinge, lambda, SolverKind::Sdca,
            Backend::Native, "artifacts", NetworkModel::ec2_like(), 5 + class as u64,
        )?;
        let spec = AlgorithmSpec::Cocoa { h, beta_k: 1.0, solver: SolverKind::Sdca };
        let budget = Budget { rounds: 25, target_gap: 1e-3, target_subopt: 0.0 };
        let trace = run(&mut cluster, &spec, budget, 1, None, "ovr")?;
        let w = cluster.w.clone();
        cluster.shutdown();
        let last = trace.rows.last().unwrap();
        println!(
            "  class {class}: {} rounds, gap {:.2e}, {} vectors, sim {:.2}s",
            last.round, last.gap, last.vectors, last.sim_time_s
        );
        models.push(w);
    }

    // multiclass prediction: argmax_c w_c . x
    let mut correct = 0usize;
    for i in 0..N {
        let scores: Vec<f64> = models
            .iter()
            .map(|w| base.features.row_dot(i, w))
            .collect();
        let pred = scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        if pred == classes[i] {
            correct += 1;
        }
    }
    let acc = correct as f64 / N as f64;
    println!("training accuracy: {:.2}% ({} / {N})", 100.0 * acc, correct);
    anyhow::ensure!(acc > 0.9, "OvR accuracy suspiciously low: {acc}");
    Ok(())
}
