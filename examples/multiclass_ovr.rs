//! Multiclass classification via one-vs-rest — the paper's problem class
//! (1) covers any convex loss of linear predictors; this example shows the
//! framework as a downstream user would apply it to a C-class problem:
//! C binary CoCoA-trained SVMs over the same partitioned data.
//!
//! The C models come out of ONE session: the per-worker curvature caches
//! are label-independent, so [`Session::set_labels`] +
//! [`Session::reset`] retrains each class without rebuilding the cluster
//! (the old version of this example paid a cold build per class). The
//! per-round models are published through a [`SnapshotSink`] and the
//! final argmax prediction runs through a [`MulticlassScorer`] — the
//! same serving path `cocoa serve` uses. The example then rebuilds one
//! cold session per class with the same seed and asserts the warm-start
//! models match bit for bit (and therefore score identically).
//!
//! ```bash
//! cargo run --release --example multiclass_ovr
//! ```

use cocoa::data::{Dataset, DenseMatrix, Features};
use cocoa::prelude::*;
use cocoa::util::Rng;

const CLASSES: usize = 3;
const N: usize = 6_000;
const D: usize = 20;

/// Gaussian blobs around C well-separated centroids.
fn make_multiclass(n: usize, d: usize, seed: u64) -> (Dataset, Vec<usize>) {
    let mut rng = Rng::seed_from_u64(seed);
    let centroids: Vec<Vec<f64>> = (0..CLASSES)
        .map(|_| (0..d).map(|_| rng.normal() * 2.0).collect())
        .collect();
    let mut rows = Vec::with_capacity(n);
    let mut classes = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % CLASSES;
        let row: Vec<f64> = centroids[c]
            .iter()
            .map(|&m| m + rng.normal())
            .collect();
        rows.push(row);
        classes.push(c);
    }
    let features = Features::Dense(DenseMatrix::from_rows(&rows));
    // placeholder labels; per-class relabeling happens below
    let mut ds = Dataset::new(features, vec![1.0; n]);
    ds.normalize_rows();
    (ds, classes)
}

/// ±1 relabeling for one-vs-rest: +1 for `class`, -1 for the rest.
fn ovr_labels(classes: &[usize], class: usize) -> Vec<f64> {
    classes
        .iter()
        .map(|&c| if c == class { 1.0 } else { -1.0 })
        .collect()
}

fn main() -> cocoa::Result<()> {
    let (base, classes) = make_multiclass(N, D, 77);
    let lambda = 1.0 / N as f64;
    let k = 4;
    let h = N / k;
    let seed = 5;
    let stopping = || GapBelow::new(1e-3).or(MaxRounds::new(25));

    println!("one-vs-rest: {CLASSES} classes, n={N}, d={D}, K={k} (one warm session)");
    let mut session = Trainer::on(&base)
        .workers(k)
        .partition_strategy(PartitionStrategy::RoundRobin)
        .loss(LossKind::Hinge)
        .lambda(lambda)
        .network(NetworkModel::ec2_like())
        .seed(seed)
        .label("ovr")
        .build()?;
    // set_labels never moves the dataset fingerprint, so one sink's
    // identity covers every class's run
    let mut sink = SnapshotSink::for_session(&session, 1);
    let handle = sink.handle();
    let mut algo = Cocoa::new(h);

    let mut models: Vec<ModelSnapshot> = Vec::with_capacity(CLASSES);
    for class in 0..CLASSES {
        session.set_labels(&ovr_labels(&classes, class))?;
        session.reset()?;
        let trace = {
            let mut driver = session.drive(&mut algo, stopping())?;
            driver.observe(&mut sink)?;
            driver.drain()?
        };
        let last = trace.rows.last().unwrap();
        println!(
            "  class {class}: {} rounds, gap {:.2e}, {} vectors, sim {:.2}s",
            last.round, last.gap, last.vectors, last.sim_time_s
        );
        models.push((*handle.current()).clone());
    }
    session.shutdown();

    // warm restarts must match cold training exactly: rebuild a fresh
    // session per class (same seed, same relabeled data) and compare the
    // models bit for bit — identical models score identically, so the
    // per-class accuracies agree by construction, and we assert both
    for (class, warm) in models.iter().enumerate() {
        let mut ds = base.clone();
        ds.labels = ovr_labels(&classes, class);
        let mut cold = Trainer::on(&ds)
            .workers(k)
            .partition_strategy(PartitionStrategy::RoundRobin)
            .loss(LossKind::Hinge)
            .lambda(lambda)
            .network(NetworkModel::ec2_like())
            .seed(seed)
            .label("ovr")
            .build()?;
        cold.run(&mut Cocoa::new(h), stopping())?;
        let w_cold = cold.w().to_vec();
        cold.shutdown();

        let bit_identical = warm.w.len() == w_cold.len()
            && warm.w.iter().zip(&w_cold).all(|(a, b)| a.to_bits() == b.to_bits());
        if !bit_identical {
            return Err(Error::Runtime {
                message: format!("class {class}: warm-restart model differs from cold training"),
            });
        }
        let binary_acc = |w: &[f64]| {
            (0..N)
                .filter(|&i| (base.features.row_dot(i, w) >= 0.0) == (classes[i] == class))
                .count()
        };
        let (warm_acc, cold_acc) = (binary_acc(&warm.w), binary_acc(&w_cold));
        if warm_acc != cold_acc {
            return Err(Error::Runtime {
                message: format!("class {class}: warm acc {warm_acc} != cold acc {cold_acc}"),
            });
        }
        println!("  class {class}: warm == cold (binary accuracy {warm_acc}/{N})");
    }

    // multiclass prediction: argmax_c w_c . x, through the serving path
    let scorer = MulticlassScorer::new(models)?;
    let preds = scorer.predict(&base.features)?;
    let correct = preds.iter().zip(&classes).filter(|(p, c)| p == c).count();
    let acc = correct as f64 / N as f64;
    println!("training accuracy: {:.2}% ({} / {N})", 100.0 * acc, correct);
    if acc <= 0.9 {
        return Err(Error::Runtime {
            message: format!("OvR accuracy suspiciously low: {acc}"),
        });
    }
    Ok(())
}
