//! Multiclass classification via one-vs-rest — the paper's problem class
//! (1) covers any convex loss of linear predictors; this example shows the
//! framework as a downstream user would apply it to a C-class problem:
//! C independent CoCoA-trained binary SVMs over the same partitioned data.
//!
//! ```bash
//! cargo run --release --example multiclass_ovr
//! ```

use cocoa::data::{Dataset, DenseMatrix, Features};
use cocoa::prelude::*;
use cocoa::util::Rng;

const CLASSES: usize = 3;
const N: usize = 6_000;
const D: usize = 20;

/// Gaussian blobs around C well-separated centroids.
fn make_multiclass(n: usize, d: usize, seed: u64) -> (Dataset, Vec<usize>) {
    let mut rng = Rng::seed_from_u64(seed);
    let centroids: Vec<Vec<f64>> = (0..CLASSES)
        .map(|_| (0..d).map(|_| rng.normal() * 2.0).collect())
        .collect();
    let mut rows = Vec::with_capacity(n);
    let mut classes = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % CLASSES;
        let row: Vec<f64> = centroids[c]
            .iter()
            .map(|&m| m + rng.normal())
            .collect();
        rows.push(row);
        classes.push(c);
    }
    let features = Features::Dense(DenseMatrix::from_rows(&rows));
    // placeholder labels; per-class relabeling happens below
    let mut ds = Dataset::new(features, vec![1.0; n]);
    ds.normalize_rows();
    (ds, classes)
}

fn main() -> cocoa::Result<()> {
    let (base, classes) = make_multiclass(N, D, 77);
    let lambda = 1.0 / N as f64;
    let k = 4;
    let h = N / k;

    println!("one-vs-rest: {CLASSES} classes, n={N}, d={D}, K={k}");
    let mut models: Vec<Vec<f64>> = Vec::with_capacity(CLASSES);
    for class in 0..CLASSES {
        // relabel: +1 for `class`, -1 for the rest
        let mut ds = base.clone();
        for (label, &c) in ds.labels.iter_mut().zip(&classes) {
            *label = if c == class { 1.0 } else { -1.0 };
        }
        let mut session = Trainer::on(&ds)
            .workers(k)
            .partition_strategy(PartitionStrategy::RoundRobin)
            .loss(LossKind::Hinge)
            .lambda(lambda)
            .network(NetworkModel::ec2_like())
            .seed(5 + class as u64)
            .label("ovr")
            .build()?;
        let stopping = GapBelow::new(1e-3).or(MaxRounds::new(25));
        let trace = session.run(&mut Cocoa::new(h), stopping)?;
        let w = session.w().to_vec();
        session.shutdown();
        let last = trace.rows.last().unwrap();
        println!(
            "  class {class}: {} rounds, gap {:.2e}, {} vectors, sim {:.2}s",
            last.round, last.gap, last.vectors, last.sim_time_s
        );
        models.push(w);
    }

    // multiclass prediction: argmax_c w_c . x
    let mut correct = 0usize;
    for i in 0..N {
        let scores: Vec<f64> = models
            .iter()
            .map(|w| base.features.row_dot(i, w))
            .collect();
        let pred = scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        if pred == classes[i] {
            correct += 1;
        }
    }
    let acc = correct as f64 / N as f64;
    println!("training accuracy: {:.2}% ({} / {N})", 100.0 * acc, correct);
    if acc <= 0.9 {
        return Err(Error::Runtime {
            message: format!("OvR accuracy suspiciously low: {acc}"),
        });
    }
    Ok(())
}
