//! Sparse high-dimensional workload (the paper's rcv1 regime): text-like
//! tf-idf features, n >> d storage-sparse, K = 8 workers.
//!
//! ```bash
//! cargo run --release --example sparse_text
//! ```
//!
//! Exercises the CSR path end-to-end and contrasts the two communication
//! patterns the paper highlights for this regime: in d = 20,000 dimensions
//! every communicated vector is 160 KB, so per-update communication
//! (naive CD) is hopeless while CoCoA amortizes it over a full local pass.
//! Also demonstrates the LibSVM round-trip (export -> reload), and runs
//! all three algorithms on one warm-started session.

use cocoa::data::{rcv1_like, read_libsvm, write_libsvm};
use cocoa::prelude::*;

fn main() -> anyhow::Result<()> {
    let n = 30_000;
    let d = 20_000;
    let k = 8;
    let data = rcv1_like(n, d, 12, 0.1, 9);
    println!(
        "sparse_text: n={n} d={d} nnz={} (density {:.4}%) K={k}",
        data.nnz(),
        100.0 * data.density()
    );

    // LibSVM round-trip: the same loader would ingest the real rcv1
    let path = std::env::temp_dir().join("cocoa_rcv1_like.svm");
    write_libsvm(&data, &path)?;
    let reloaded = read_libsvm(&path, d)?;
    anyhow::ensure!(reloaded.n() == n, "libsvm round-trip lost rows");
    println!(
        "libsvm round-trip ok: {} ({} bytes)",
        path.display(),
        std::fs::metadata(&path)?.len()
    );

    let lambda = 1.0 / n as f64;
    let h = n / k;
    let net = NetworkModel::ec2_like();
    let mut session = Trainer::on(&data)
        .workers(k)
        .loss(LossKind::Hinge)
        .lambda(lambda)
        .network(net)
        .seed(13)
        .label("rcv1_like")
        .build()?;

    println!(
        "\n{:<14} {:>7} {:>12} {:>12} {:>14} {:>12}",
        "algorithm", "rounds", "gap", "subopt-ish", "vectors", "sim t (s)"
    );
    let mut algos: Vec<Box<dyn Algorithm>> = vec![
        Box::new(Cocoa::new(h)),
        Box::new(LocalSgd::new(h)),
        Box::new(MinibatchSgd::new(h)),
    ];
    for algo in algos.iter_mut() {
        session.reset()?;
        let trace =
            session.run(algo.as_mut(), DriverSpec::new(MaxRounds::new(15)).eval_every(5))?;
        let last = trace.rows.last().unwrap();
        println!(
            "{:<14} {:>7} {:>12.2e} {:>12.6} {:>14} {:>12.2}",
            algo.name(),
            last.round,
            last.gap,
            last.primal,
            last.vectors,
            last.sim_time_s
        );
        trace.to_csv(format!("results/sparse_text/{}.csv", algo.name()))?;
    }
    session.shutdown();

    // the naive pattern, costed without running 30k rounds: each update
    // ships one d-vector through a 5 ms + bandwidth round
    let one_round = net.round_time(2e-6, 2 * k, d);
    println!(
        "\nnaive distributed CD would need ~{n} rounds x {:.1} ms = {:.0} s of pure communication",
        one_round * 1e3,
        one_round * n as f64 / k as f64
    );
    println!("for the same {n} coordinate updates CoCoA communicated in {} rounds.", 15);
    Ok(())
}
