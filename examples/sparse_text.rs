//! Sparse high-dimensional workload (the paper's rcv1 regime): text-like
//! tf-idf features, storage-sparse, trained **out of core** from on-disk
//! shards instead of an in-memory matrix.
//!
//! ```bash
//! cargo run --release --example sparse_text
//! ```
//!
//! The flow a real rcv1-scale run would use:
//!
//! 1. the dataset sits on disk as LibSVM text (here we synthesize and
//!    export one so the example is self-contained);
//! 2. `shard_libsvm` **streams** it into one checksummed CSR shard file
//!    per worker + a manifest — memory stays O(rows), never O(nnz);
//! 3. `Trainer::on_shards` trains from the shard set, each worker
//!    memory-mapping only its own shard;
//! 4. the trajectory is bit-identical to loading everything in RAM —
//!    asserted below, not just claimed.
//!
//! The communication contrast the paper highlights still applies: in
//! d = 20,000 dimensions every communicated vector is 160 KB, so
//! per-update communication (naive CD) is hopeless while CoCoA
//! amortizes one round trip over a full local pass.
//! See `docs/DATA.md` for the data-layer contract.

use cocoa::data::{rcv1_like, read_libsvm, shard_libsvm, write_libsvm, PartitionStrategy};
use cocoa::prelude::*;

fn main() -> anyhow::Result<()> {
    let n = 30_000;
    let d = 20_000;
    let k = 8;

    // a self-contained stand-in for "rcv1_train.binary on disk"
    let dir = std::env::temp_dir().join("cocoa_sparse_text");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;
    let svm_path = dir.join("rcv1_like.svm");
    write_libsvm(&rcv1_like(n, d, 12, 0.1, 9), &svm_path)?;
    println!(
        "sparse_text: {} ({} bytes of libsvm text)",
        svm_path.display(),
        std::fs::metadata(&svm_path)?.len()
    );

    // stream the file into K shards: two passes over the text (one to
    // count rows for contiguous blocks, one to write), no full matrix
    let shard_dir = dir.join("shards");
    let set = shard_libsvm(&svm_path, &shard_dir, k, PartitionStrategy::Contiguous, 0, d, false)?;
    println!(
        "sharded n={} d={} nnz={} into K={} files under {} ({:.1} MiB on disk, mode {:?})",
        set.n(),
        set.d(),
        set.nnz(),
        set.k(),
        shard_dir.display(),
        set.total_bytes() as f64 / (1024.0 * 1024.0),
        set.mode()
    );

    let lambda = 1.0 / n as f64;
    let h = n / k;
    let net = NetworkModel::ec2_like();

    // train from the shards: workers read mmap-backed row views
    let mut session = Trainer::on_shards(&set)
        .loss(LossKind::Hinge)
        .lambda(lambda)
        .network(net)
        .seed(13)
        .label("rcv1_like_ooc")
        .build()?;
    let trace =
        session.run(&mut Cocoa::new(h), DriverSpec::new(MaxRounds::new(15)).eval_every(5))?;
    let last = trace.rows.last().unwrap();
    println!(
        "\nshard-backed cocoa: round {} gap {:.2e} primal {:.6} ({} vectors, sim {:.2} s)",
        last.round, last.gap, last.primal, last.vectors, last.sim_time_s
    );
    trace.to_csv("results/sparse_text/cocoa_shards.csv")?;
    let w_shards = session.w().to_vec();
    session.shutdown();

    // the contract: the same rows loaded in RAM produce the same bits
    let data = read_libsvm(&svm_path, d)?;
    let mut session = Trainer::on(&data)
        .workers(k)
        .loss(LossKind::Hinge)
        .lambda(lambda)
        .network(net)
        .seed(13)
        .label("rcv1_like_mem")
        .build()?;
    let mem_trace =
        session.run(&mut Cocoa::new(h), DriverSpec::new(MaxRounds::new(15)).eval_every(5))?;
    let mem_last = mem_trace.rows.last().unwrap();
    anyhow::ensure!(
        mem_last.gap.to_bits() == last.gap.to_bits()
            && session.w().iter().zip(&w_shards).all(|(a, b)| a.to_bits() == b.to_bits()),
        "shard-backed run diverged from the in-memory run"
    );
    println!("in-memory twin matched bit for bit (gap {:.2e}, identical w)", mem_last.gap);
    session.shutdown();

    // the naive pattern, costed without running 30k rounds: each update
    // ships one d-vector through a 5 ms + bandwidth round
    let one_round = net.round_time(2e-6, 2 * k, d);
    println!(
        "\nnaive distributed CD would need ~{n} rounds x {:.1} ms = {:.0} s of pure communication",
        one_round * 1e3,
        one_round * n as f64 / k as f64
    );
    println!("for the same {n} coordinate updates CoCoA communicated in {} rounds.", 15);

    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
