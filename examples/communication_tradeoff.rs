//! The communication/computation trade-off (the paper's Figure 3 story),
//! across interconnects: the best H depends on how expensive a round is.
//!
//! ```bash
//! cargo run --release --example communication_tradeoff
//! ```
//!
//! Sweeps H over four orders of magnitude on three network models
//! (EC2-like, InfiniBand-like, multicore) and prints the simulated time to
//! a fixed duality gap. On the slow network large H wins decisively; as
//! communication gets cheaper the optimum shifts toward smaller H —
//! exactly the "freely steer the trade-off" knob the paper motivates.
//! One session per network; every H point warm-starts the same threads.
//!
//! Runs on the byte-exact `counted` transport, so the simulated time is
//! driven by measured wire bytes (headers, sparse dw encodings) rather
//! than the analytic vector count; the per-kind ledger of the last run is
//! printed at the end.

use cocoa::data::cov_like;
use cocoa::prelude::*;

fn main() -> cocoa::Result<()> {
    let data = cov_like(20_000, 54, 0.1, 3);
    let k = 4;
    let lambda = 1.0 / data.n() as f64;
    let nets: [(&str, NetworkModel); 3] = [
        ("ec2_like", NetworkModel::ec2_like()),
        ("infiniband", NetworkModel::infiniband()),
        ("multicore", NetworkModel::multicore()),
    ];
    let h_grid = [5usize, 50, 500, 5000];
    let target_gap = 3e-3;

    println!("time (simulated s) to duality gap <= {target_gap:.0e}, n={} K={k}", data.n());
    print!("{:<12}", "network");
    for h in h_grid {
        print!(" {:>12}", format!("H={h}"));
    }
    println!();

    let mut last_run: Option<(u64, Vec<(String, u64, u64)>)> = None;
    for (name, net) in nets {
        let mut session = Trainer::on(&data)
            .workers(k)
            .loss(LossKind::Hinge)
            .lambda(lambda)
            .network(net)
            .transport(TransportKind::Counted)
            .seed(5)
            .label("tradeoff")
            .build()?;
        print!("{name:<12}");
        for h in h_grid {
            session.reset()?;
            // equal total-steps budget across H; evaluation cadence scaled
            // so instrumentation stays cheap for tiny H
            let rule = GapBelow::new(target_gap)
                .or(MaxRounds::new((600_000 / h as u64).max(120)));
            let spec = DriverSpec::new(rule).eval_every((2_000 / h as u64).max(1));
            let trace = session.run(&mut Cocoa::new(h), spec)?;
            match trace.time_to_gap(target_gap) {
                Some(t) => print!(" {:>12.3}", t),
                None => print!(" {:>12}", "-"),
            }
            last_run = session.ledger().map(|ledger| {
                let rows = ledger
                    .rows()
                    .filter(|(_, msgs, _)| *msgs > 0)
                    .map(|(kind, msgs, bytes)| (kind.name().to_string(), msgs, bytes))
                    .collect();
                (session.stats().bytes_measured, rows)
            });
        }
        println!();
        session.shutdown();
    }
    if let Some((algo_bytes, rows)) = last_run {
        println!(
            "\nlast run (H={}, multicore): {:.2} MB of algorithm traffic on the wire;",
            h_grid[h_grid.len() - 1],
            algo_bytes as f64 / 1e6
        );
        println!("per-kind ledger (headers + sparse dw encodings, eval counted separately):");
        for (kind, msgs, bytes) in rows {
            println!("  {kind:<13} {msgs:>8} msgs {bytes:>14} B");
        }
    }
    println!("\nReading: on the EC2-like network (5 ms rounds) H must be large;");
    println!("on multicore (memory-speed rounds) small H catches up — the paper's");
    println!("framework tunes one knob to span both worlds.");
    Ok(())
}
