//! End-to-end driver: the full three-layer system on a real-scale workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_cocoa
//! ```
//!
//! Pipeline proved here:
//!   1. generate the cov-regime dataset (n = 100,000 x d = 54; the paper's
//!      forest-cover regime at reduced n), partition over K = 4 workers;
//!   2. train with CoCoA where every worker's inner loop is the AOT
//!      JAX/Pallas `local_sdca` kernel executed through PJRT (L1+L2),
//!      coordinated by the rust leader (L3) — python is NOT running;
//!   3. train the identical problem on the native rust backend and check
//!      the two backends agree;
//!   4. run the mini-batch SDCA baseline and report CoCoA's advantage to
//!      .001-accurate primal suboptimality (the paper's headline metric);
//!   5. write traces to results/e2e/*.csv (recorded in EXPERIMENTS.md).

use cocoa::data::cov_like;
use cocoa::objective;
use cocoa::prelude::*;

const N: usize = 100_000;
const D: usize = 54;
const K: usize = 4;

fn main() -> anyhow::Result<()> {
    println!("== e2e: CoCoA on cov-like {N}x{D}, K={K}, hinge SVM ==");
    let data = cov_like(N, D, 0.1, 11);
    let lambda = 1e-5;
    let h = N / K; // one full local pass per outer round

    // reference optimum for the suboptimality axis
    println!("computing reference optimum (serial SDCA to gap < 1e-8)...");
    let (p_star, _) = objective::compute_optimum(&data, lambda, &cocoa::loss::Hinge, 1e-8, 200);
    println!("P* = {p_star:.9}");

    // stop at 2e-4 suboptimality, or 40 rounds, whichever first — the
    // composable-rule spelling of the old Budget (rebuilt per run: rules
    // may carry state, so each run gets a fresh one)
    let stopping = || SuboptBelow::new(2e-4).or(MaxRounds::new(40));
    let trainer = |backend: Backend| {
        Trainer::on(&data)
            .workers(K)
            .loss(LossKind::Hinge)
            .lambda(lambda)
            .backend(backend)
            .artifacts_dir("artifacts")
            .network(NetworkModel::ec2_like())
            .seed(21)
            .label("cov_e2e")
    };

    // --- PJRT backend: inner loop = AOT Pallas kernel through XLA ---
    // (Trainer::build returns the typed MissingArtifacts error when
    // `make artifacts` has not run.)
    let mut session = match trainer(Backend::Pjrt).build() {
        Err(Error::MissingArtifacts { dir }) => {
            anyhow::bail!("{dir}/ not built — run `make artifacts` first")
        }
        other => other?,
    };
    session.set_reference_optimum(Some(p_star));
    println!("\n[pjrt backend] running up to 40 rounds of H={h}...");
    let trace_pjrt = session.run(&mut Cocoa::new(h), stopping())?;
    session.shutdown();
    report("pjrt", &trace_pjrt);
    trace_pjrt.to_csv("results/e2e/cocoa_pjrt.csv")?;

    // --- native backend: same problem, same seeds ---
    let mut session = trainer(Backend::Native).build()?;
    session.set_reference_optimum(Some(p_star));
    println!("\n[native backend] running the identical configuration...");
    let trace_native = session.run(&mut Cocoa::new(h), stopping())?;
    report("native", &trace_native);
    trace_native.to_csv("results/e2e/cocoa_native.csv")?;

    // backend parity: both reach the same objective region
    let p_pjrt = trace_pjrt.rows.last().unwrap().primal;
    let p_native = trace_native.rows.last().unwrap().primal;
    let rel = (p_pjrt - p_native).abs() / p_native.abs().max(1e-12);
    println!("\nbackend parity: P_pjrt={p_pjrt:.8} P_native={p_native:.8} (rel diff {rel:.2e})");
    anyhow::ensure!(rel < 1e-2, "backends disagree beyond f32 tolerance");

    // --- the baseline: mini-batch SDCA at the same per-round batch,
    //     warm-started on the same native worker threads ---
    session.reset()?;
    println!("\n[baseline] mini-batch SDCA, same batch size per round...");
    let mb_spec = DriverSpec::new(SuboptBelow::new(2e-4).or(MaxRounds::new(400))).eval_every(10);
    let trace_mb = session.run(&mut MinibatchCd::new(h), mb_spec)?;
    session.shutdown();
    report("minibatch_cd", &trace_mb);
    trace_mb.to_csv("results/e2e/minibatch_cd.csv")?;

    // --- headline ---
    let target = 1e-3;
    let t_cocoa = trace_native.time_to_subopt(target);
    let t_mb = trace_mb.time_to_subopt(target);
    let v_cocoa = trace_native.vectors_to_subopt(target);
    let v_mb = trace_mb.vectors_to_subopt(target);
    println!("\n== headline: time/communication to .001-accurate solution ==");
    println!(
        "cocoa:        t = {}   vectors = {}",
        t_cocoa.map(|t| format!("{t:.2}s")).unwrap_or("-".into()),
        v_cocoa.map(|v| v.to_string()).unwrap_or("-".into())
    );
    println!(
        "minibatch_cd: t = {}   vectors = {}",
        t_mb.map(|t| format!("{t:.2}s")).unwrap_or("-".into()),
        v_mb.map(|v| v.to_string()).unwrap_or("-".into())
    );
    match (t_cocoa, t_mb) {
        (Some(a), Some(b)) => {
            println!("speedup: {:.1}x (paper reports ~25x vs best competitor)", b / a)
        }
        (Some(_), None) => {
            println!("speedup: >{}x (baseline never reached target)", mb_budget.rounds)
        }
        _ => println!("warning: cocoa did not reach the target within budget"),
    }
    anyhow::ensure!(t_cocoa.is_some(), "e2e failed: CoCoA must reach .001 suboptimality");
    println!("\ntraces -> results/e2e/*.csv");
    Ok(())
}

fn report(name: &str, trace: &Trace) {
    println!(
        "  {:<8} {:>6} {:>12} {:>12} {:>12} {:>12}",
        "backend", "round", "P(w)", "gap", "subopt", "sim t (s)"
    );
    for row in trace.rows.iter().filter(|r| r.round.is_multiple_of(5) || r.round <= 2) {
        println!(
            "  {:<8} {:>6} {:>12.6} {:>12.2e} {:>12.2e} {:>12.2}",
            name, row.round, row.primal, row.gap, row.primal_subopt, row.sim_time_s
        );
    }
    let last = trace.rows.last().unwrap();
    println!(
        "  {name}: finished round {} | gap {:.2e} | subopt {:.2e} | {} vectors | sim {:.2}s",
        last.round, last.gap, last.primal_subopt, last.vectors, last.sim_time_s
    );
}
